//! PJRT-backed [`ModelRuntime`]: load HLO text, compile once per
//! (kind, batch size), execute on the request path.
//!
//! Interchange contract (see /opt/xla-example/README.md and
//! `python/compile/aot.py`): artifacts are HLO **text**, parsed with
//! `HloModuleProto::from_text_file` (which reassigns instruction ids —
//! jax ≥ 0.5 emits 64-bit ids that xla_extension 0.5.1 would reject in
//! proto form).  Executables return a 1-tuple (lowered with
//! `return_tuple=True`) whose single element is itself the flat output
//! tuple.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::{Manifest, ModelArtifacts, ModelMeta};
use super::{EvalOut, ModelRuntime, TrainOut};
use crate::tensor::{ParamVec, Tensor};

pub struct XlaRuntime {
    client: xla::PjRtClient,
    meta: ModelMeta,
    train_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    eval_exe: xla::PjRtLoadedExecutable,
    execs: u64,
    // ---- hot-path marshalling caches (EXPERIMENTS.md §Perf) ----
    /// Zero momentum literals, reused when mu == 0 (the momentum
    /// inputs cannot affect any output then: new_mom = 0·m + g).
    zero_mom: Option<Vec<xla::Literal>>,
    /// Cached probe-batch literals keyed by a content fingerprint —
    /// the probe is constant for a whole run, so its ~400 KB of eval
    /// input is marshalled once instead of per iteration.
    eval_cache: Option<(u64, xla::Literal, xla::Literal)>,
}

impl XlaRuntime {
    /// Load every compiled batch size for `model` from the artifacts
    /// directory (use [`XlaRuntime::load_batches`] to restrict).
    pub fn load(artifacts_dir: impl AsRef<Path>, model: &str) -> Result<Self> {
        Self::load_batches(artifacts_dir, model, None)
    }

    /// Load with an optional batch-size restriction (compiling fewer
    /// executables is faster for tests that only need one).
    pub fn load_batches(
        artifacts_dir: impl AsRef<Path>,
        model: &str,
        only: Option<&[usize]>,
    ) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let arts = manifest.model(model)?;
        Self::from_artifacts(arts, only)
    }

    pub fn from_artifacts(arts: &ModelArtifacts, only: Option<&[usize]>) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        let mut train_exes = BTreeMap::new();
        let mut batches = Vec::new();
        for (&batch, path) in &arts.train_paths {
            if let Some(only) = only {
                if !only.contains(&batch) {
                    continue;
                }
            }
            let exe = compile_text(&client, path)
                .with_context(|| format!("compiling {}", path.display()))?;
            train_exes.insert(batch, exe);
            batches.push(batch);
        }
        if train_exes.is_empty() {
            bail!("no train executables selected for '{}'", arts.meta.name);
        }
        let eval_exe = compile_text(&client, &arts.eval_path)
            .with_context(|| format!("compiling {}", arts.eval_path.display()))?;
        let mut meta = arts.meta.clone();
        meta.train_batches = batches;
        Ok(Self {
            client,
            meta,
            train_exes,
            eval_exe,
            execs: 0,
            zero_mom: None,
            eval_cache: None,
        })
    }

    fn params_to_literals(&self, params: &ParamVec, out: &mut Vec<xla::Literal>) -> Result<()> {
        for t in &params.tensors {
            out.push(tensor_to_literal(t)?);
        }
        Ok(())
    }
}

fn compile_text(
    client: &xla::PjRtClient,
    path: &Path,
) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
    )
    .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client.compile(&comp).map_err(|e| anyhow!("compile: {e}"))
}

fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    // Direct shape+bytes construction: one memcpy, no reshape pass
    // (§Perf: Literal::vec1 + reshape costs ~3× more on this path).
    let bytes = unsafe {
        std::slice::from_raw_parts(t.data().as_ptr() as *const u8, t.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        t.shape(),
        bytes,
    )
    .map_err(|e| anyhow!("literal from {:?}: {e}", t.shape()))
}

fn slice_to_literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)
        .map_err(|e| anyhow!("literal from {dims:?}: {e}"))
}

fn slice_to_literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, dims, bytes)
        .map_err(|e| anyhow!("literal from {dims:?}: {e}"))
}

fn literal_to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e}"))
}

/// Cheap content fingerprint for the eval-input cache: length plus 16
/// sampled elements.  The probe batch is immutable for a run, so this
/// only needs to distinguish "same probe" from "different probe".
fn fingerprint(x: &[f32], y: &[i32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x1000_0000_01b3);
    };
    mix(x.len() as u64);
    mix(y.len() as u64);
    let step = (x.len() / 16).max(1);
    for i in (0..x.len()).step_by(step) {
        mix(x[i].to_bits() as u64);
    }
    for &v in y.iter().take(16) {
        mix(v as u64);
    }
    h
}

impl ModelRuntime for XlaRuntime {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn train_step(
        &mut self,
        params: &ParamVec,
        momentum: &ParamVec,
        x: &[f32],
        y: &[i32],
        mbs: usize,
        lr: f32,
        mu: f32,
    ) -> Result<TrainOut> {
        let exe = self
            .train_exes
            .get(&mbs)
            .ok_or_else(|| anyhow!("no train executable for batch {mbs}"))?;
        let (h, w, c) = self.meta.input_shape;
        if x.len() != mbs * h * w * c || y.len() != mbs {
            bail!(
                "bad batch: x {} (want {}), y {} (want {mbs})",
                x.len(),
                mbs * h * w * c,
                y.len()
            );
        }
        let n = self.meta.param_shapes.len();
        let mut args: Vec<xla::Literal> = Vec::with_capacity(2 * n + 4);
        self.params_to_literals(params, &mut args)?;
        if mu == 0.0 {
            // Momentum inputs are algebraically dead (new_mom = g):
            // reuse cached zero literals instead of re-marshalling
            // ~param_count·4 bytes per step.
            if self.zero_mom.is_none() {
                let zeros = ParamVec::zeros_like(params);
                let mut lits = Vec::with_capacity(n);
                for t in &zeros.tensors {
                    lits.push(tensor_to_literal(t)?);
                }
                self.zero_mom = Some(lits);
            }
            for lit in self.zero_mom.as_ref().unwrap() {
                args.push(lit.reshape(
                    &lit.array_shape()
                        .map_err(|e| anyhow!("{e}"))?
                        .dims()
                        .to_vec(),
                )?);
            }
        } else {
            self.params_to_literals(momentum, &mut args)?;
        }
        args.push(slice_to_literal_f32(x, &[mbs, h, w, c])?);
        args.push(slice_to_literal_i32(y, &[mbs])?);
        args.push(xla::Literal::scalar(lr));
        args.push(xla::Literal::scalar(mu));

        let result = exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("execute train: {e}"))?;
        self.execs += 1;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?
            .to_tuple()
            .map_err(|e| anyhow!("untuple: {e}"))?;
        if tuple.len() != 2 * n + 2 {
            bail!("train output arity {} != {}", tuple.len(), 2 * n + 2);
        }

        let mut new_params = ParamVec::default();
        let mut new_mom = ParamVec::default();
        for (i, shape) in self.meta.param_shapes.iter().enumerate() {
            new_params
                .tensors
                .push(Tensor::new(shape.clone(), literal_to_vec_f32(&tuple[i])?));
            new_mom.tensors.push(Tensor::new(
                shape.clone(),
                literal_to_vec_f32(&tuple[n + i])?,
            ));
        }
        let loss = tuple[2 * n].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?[0];
        let correct =
            tuple[2 * n + 1].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?[0];
        Ok(TrainOut { params: new_params, momentum: new_mom, loss, correct })
    }

    fn eval_step(&mut self, params: &ParamVec, x: &[f32], y: &[i32]) -> Result<EvalOut> {
        let b = self.meta.eval_batch;
        let (h, w, c) = self.meta.input_shape;
        if x.len() != b * h * w * c || y.len() != b {
            bail!("bad eval batch: x {} y {}", x.len(), y.len());
        }
        let n = self.meta.param_shapes.len();
        let mut args: Vec<xla::Literal> = Vec::with_capacity(n + 2);
        self.params_to_literals(params, &mut args)?;
        let fp = fingerprint(x, y);
        if self.eval_cache.as_ref().map(|(f, _, _)| *f) != Some(fp) {
            let xl = slice_to_literal_f32(x, &[b, h, w, c])?;
            let yl = slice_to_literal_i32(y, &[b])?;
            self.eval_cache = Some((fp, xl, yl));
        }
        let (_, xl, yl) = self.eval_cache.as_ref().unwrap();
        // Reshape-to-same-dims is the crate's cheap literal clone.
        args.push(xl.reshape(&[b as i64, h as i64, w as i64, c as i64])?);
        args.push(yl.reshape(&[b as i64])?);

        let result = self
            .eval_exe
            .execute::<xla::Literal>(&args)
            .map_err(|e| anyhow!("execute eval: {e}"))?;
        self.execs += 1;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?
            .to_tuple()
            .map_err(|e| anyhow!("untuple: {e}"))?;
        if tuple.len() != 2 {
            bail!("eval output arity {}", tuple.len());
        }
        let loss = tuple[0].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?[0];
        let correct = tuple[1].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?[0];
        Ok(EvalOut { loss, correct })
    }

    fn exec_count(&self) -> u64 {
        self.execs
    }
}

impl std::fmt::Debug for XlaRuntime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaRuntime")
            .field("model", &self.meta.name)
            .field("platform", &self.client.platform_name())
            .field("train_batches", &self.meta.train_batches)
            .field("execs", &self.execs)
            .finish()
    }
}
