//! Artifact-free [`ModelRuntime`]: a softmax regression with real,
//! host-computed gradients.
//!
//! The coordinator's behaviour (gating, aggregation, allocation,
//! scheduling) is independent of *which* differentiable model produces
//! the losses, so every coordinator test and micro-bench runs against
//! this runtime: real learning dynamics, zero XLA dependency, ~µs per
//! step.  The input is a flattened (4, 4, 2) "image" (32 features, 10
//! classes ⇒ 330 parameters).
//!
//! **Fast path (DESIGN.md §13).**  The forward (logits GEMM), the
//! rank-1 gradient accumulation and the fused SGD(M) update run through
//! the runtime-dispatched [`kernels`] (scalar ↔ AVX2, bit-identical by
//! construction, `HERMES_FORCE_SCALAR` respected), and every scratch
//! buffer is reused: per-class probabilities live in a runtime-owned
//! buffer, the gradient accumulator is a caller-leased [`ParamVec`]
//! (see [`ModelRuntime::train_step_in_place`]).  Steady-state worker
//! stepping therefore performs **zero heap allocations** — asserted by
//! `tests/alloc_hotpath.rs`.  The allocating [`ModelRuntime::train_step`]
//! remains as the seed path (fresh output buffers per call) and runs
//! the exact same kernel sequence, so both paths produce identical
//! bits.

use anyhow::{bail, Result};

use super::manifest::ModelMeta;
use super::{EvalOut, ModelRuntime, TrainOut};
use crate::tensor::{kernels, ParamVec};

pub const MOCK_FEATURES: usize = 32;
pub const MOCK_CLASSES: usize = 10;

#[derive(Debug, Clone)]
pub struct MockRuntime {
    meta: ModelMeta,
    execs: u64,
    /// Per-class probability scratch (`batch × MOCK_CLASSES`), reused
    /// across steps and evals; doubles as the scaled grad-logits buffer
    /// inside a train step.
    probs: Vec<f32>,
}

impl Default for MockRuntime {
    fn default() -> Self {
        Self::new()
    }
}

impl MockRuntime {
    pub fn new() -> Self {
        MockRuntime {
            meta: ModelMeta {
                name: "mock".into(),
                input_shape: (4, 4, 2),
                num_classes: MOCK_CLASSES,
                param_shapes: vec![
                    vec![MOCK_FEATURES, MOCK_CLASSES],
                    vec![MOCK_CLASSES],
                ],
                param_count: MOCK_FEATURES * MOCK_CLASSES + MOCK_CLASSES,
                train_batches: vec![2, 4, 8, 16, 32, 64, 128, 256],
                eval_batch: 128,
            },
            execs: 0,
            probs: Vec::new(),
        }
    }

    /// logits\[b\] = x\[b\]·W + bias (dispatched GEMM), then softmax +
    /// xent in place; returns (mean xent loss, #correct) with the
    /// per-class probabilities left in `probs` for the gradient.
    ///
    /// The softmax/loss reductions stay scalar-ordered (row max, exp,
    /// denominator sum, log) — reassociating them would change bits,
    /// exactly as with `ParamVec::l2_norm` (DESIGN.md §12).
    fn forward_into(
        params: &ParamVec,
        x: &[f32],
        y: &[i32],
        batch: usize,
        probs: &mut Vec<f32>,
    ) -> (f32, f32) {
        let w = params.tensors[0].data();
        let b = params.tensors[1].data();
        probs.resize(batch * MOCK_CLASSES, 0.0);
        kernels::gemm_bias(probs, x, w, b, batch, MOCK_FEATURES, MOCK_CLASSES);
        let mut loss = 0f64;
        let mut correct = 0f32;
        for i in 0..batch {
            let row = &mut probs[i * MOCK_CLASSES..(i + 1) * MOCK_CLASSES];
            // softmax + xent
            let max = row.iter().cloned().fold(f32::MIN, f32::max);
            let mut denom = 0f32;
            for r in row.iter_mut() {
                *r = (*r - max).exp();
                denom += *r;
            }
            for r in row.iter_mut() {
                *r /= denom;
            }
            let mut argmax = 0usize;
            for c in 1..MOCK_CLASSES {
                if row[c] > row[argmax] {
                    argmax = c;
                }
            }
            let label = y[i] as usize;
            loss -= (row[label].max(1e-12) as f64).ln();
            if argmax == label {
                correct += 1.0;
            }
        }
        ((loss / batch as f64) as f32, correct)
    }

    /// The shared step body: forward, gradient accumulation into
    /// `grad`, fused SGD(M) applied to `p`/`m` in place.  Both the
    /// allocating seed path ([`ModelRuntime::train_step`]) and the
    /// pooled fast path ([`ModelRuntime::train_step_in_place`]) call
    /// this, which is what makes them bit-identical.
    #[allow(clippy::too_many_arguments)]
    fn step_core(
        &mut self,
        p: &mut ParamVec,
        m: &mut ParamVec,
        grad: &mut ParamVec,
        x: &[f32],
        y: &[i32],
        mbs: usize,
        lr: f32,
        mu: f32,
    ) -> (f32, f32) {
        let (loss, correct) = Self::forward_into(p, x, y, mbs, &mut self.probs);

        // grad_logits = probs − one_hot(y), scaled by 1/mbs in place
        // (the probabilities are not needed after this), with the bias
        // gradient accumulated in the same pass.
        grad.resize_like(p);
        grad.fill(0.0);
        let (gw_t, gb_t) = grad.tensors.split_at_mut(1);
        let gw = gw_t[0].data_mut();
        let gb = gb_t[0].data_mut();
        let inv = 1.0 / mbs as f32;
        for i in 0..mbs {
            let row = &mut self.probs[i * MOCK_CLASSES..(i + 1) * MOCK_CLASSES];
            for (c, r) in row.iter_mut().enumerate() {
                let mut g = *r;
                if y[i] as usize == c {
                    g -= 1.0;
                }
                g *= inv;
                *r = g;
                gb[c] += g;
            }
        }
        // Weight gradient: one rank-1 update per sample, in sample
        // order (fixes the per-element accumulation order).
        for i in 0..mbs {
            kernels::rank1_acc(
                gw,
                &x[i * MOCK_FEATURES..(i + 1) * MOCK_FEATURES],
                &self.probs[i * MOCK_CLASSES..(i + 1) * MOCK_CLASSES],
                MOCK_CLASSES,
            );
        }

        // SGD with momentum, matching the L2 train step semantics:
        // m ← mu·m + g;  p ← p − lr·m.
        for ((pt, mt), gt) in p
            .tensors
            .iter_mut()
            .zip(m.tensors.iter_mut())
            .zip(&grad.tensors)
        {
            kernels::sgd_momentum(pt.data_mut(), mt.data_mut(), gt.data(), lr, mu);
        }
        (loss, correct)
    }

    fn check_batch(&self, x: &[f32], y: &[i32], mbs: usize) -> Result<()> {
        if x.len() != mbs * MOCK_FEATURES || y.len() != mbs {
            bail!("mock: bad batch ({} x, {} y, mbs {mbs})", x.len(), y.len());
        }
        Ok(())
    }
}

impl ModelRuntime for MockRuntime {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn train_step(
        &mut self,
        params: &ParamVec,
        momentum: &ParamVec,
        x: &[f32],
        y: &[i32],
        mbs: usize,
        lr: f32,
        mu: f32,
    ) -> Result<TrainOut> {
        self.check_batch(x, y, mbs)?;
        self.execs += 1;
        // Seed path: fresh output + gradient buffers every call.
        let mut p = params.clone();
        let mut m = momentum.clone();
        let mut grad = ParamVec::zeros_like(params);
        let (loss, correct) = self.step_core(&mut p, &mut m, &mut grad, x, y, mbs, lr, mu);
        Ok(TrainOut { params: p, momentum: m, loss, correct })
    }

    #[allow(clippy::too_many_arguments)]
    fn train_step_in_place(
        &mut self,
        params: &mut ParamVec,
        momentum: &mut ParamVec,
        grad_scratch: &mut ParamVec,
        x: &[f32],
        y: &[i32],
        mbs: usize,
        lr: f32,
        mu: f32,
    ) -> Result<EvalOut> {
        self.check_batch(x, y, mbs)?;
        self.execs += 1;
        let (loss, correct) =
            self.step_core(params, momentum, grad_scratch, x, y, mbs, lr, mu);
        Ok(EvalOut { loss, correct })
    }

    fn eval_step(&mut self, params: &ParamVec, x: &[f32], y: &[i32]) -> Result<EvalOut> {
        let b = self.meta.eval_batch;
        if x.len() != b * MOCK_FEATURES || y.len() != b {
            bail!("mock: bad eval batch");
        }
        self.execs += 1;
        let (loss, correct) = Self::forward_into(params, x, y, b, &mut self.probs);
        Ok(EvalOut { loss, correct })
    }

    fn exec_count(&self) -> u64 {
        self.execs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::init_params;
    use crate::tensor::kernels::{with_backend, Backend};
    use crate::tensor::Tensor;
    use crate::util::rng::Xoshiro256pp;

    /// Linearly separable toy data: class templates + noise.
    fn toy_batch(
        rng: &mut Xoshiro256pp,
        n: usize,
    ) -> (Vec<f32>, Vec<i32>, [[f32; MOCK_FEATURES]; MOCK_CLASSES]) {
        let mut templates = [[0f32; MOCK_FEATURES]; MOCK_CLASSES];
        let mut trng = Xoshiro256pp::seed_from_u64(99);
        for t in templates.iter_mut() {
            for v in t.iter_mut() {
                *v = trng.normal() as f32;
            }
        }
        let mut x = Vec::with_capacity(n * MOCK_FEATURES);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.next_below(MOCK_CLASSES as u64) as usize;
            y.push(c as i32);
            for f in 0..MOCK_FEATURES {
                x.push(templates[c][f] + 0.3 * rng.normal() as f32);
            }
        }
        (x, y, templates)
    }

    #[test]
    fn mock_learns_separable_data() {
        let mut rt = MockRuntime::new();
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let mut params = init_params(rt.meta(), 1);
        let mut mom = ParamVec::zeros_like(&params);
        let mut first = 0f32;
        let mut last = 0f32;
        for step in 0..60 {
            let (x, y, _) = toy_batch(&mut rng, 16);
            let out = rt
                .train_step(&params, &mom, &x, &y, 16, 0.5, 0.0)
                .unwrap();
            params = out.params;
            mom = out.momentum;
            if step == 0 {
                first = out.loss;
            }
            last = out.loss;
        }
        assert!(last < first * 0.3, "no learning: {first} → {last}");
        assert_eq!(rt.exec_count(), 60);
    }

    #[test]
    fn zero_lr_is_identity() {
        let mut rt = MockRuntime::new();
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let params = init_params(rt.meta(), 2);
        let mom = ParamVec::zeros_like(&params);
        let (x, y, _) = toy_batch(&mut rng, 8);
        let out = rt.train_step(&params, &mom, &x, &y, 8, 0.0, 0.0).unwrap();
        assert_eq!(out.params, params);
    }

    #[test]
    fn momentum_zero_buffers_carry_raw_gradient() {
        // Mirrors the L2 pytest: new_p = p − lr·g when mu = 0.
        let mut rt = MockRuntime::new();
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let params = init_params(rt.meta(), 3);
        let mom = ParamVec::zeros_like(&params);
        let (x, y, _) = toy_batch(&mut rng, 4);
        let lr = 0.1f32;
        let out = rt.train_step(&params, &mom, &x, &y, 4, lr, 0.0).unwrap();
        for ((p_new, p_old), g) in out
            .params
            .tensors
            .iter()
            .zip(&params.tensors)
            .zip(&out.momentum.tensors)
        {
            for ((a, b), gv) in
                p_new.data().iter().zip(b_iter(p_old)).zip(g.data())
            {
                assert!((a - (b - lr * gv)).abs() < 1e-6);
            }
        }
        fn b_iter(t: &Tensor) -> std::slice::Iter<'_, f32> {
            t.data().iter()
        }
    }

    #[test]
    fn eval_matches_train_loss_on_same_batch() {
        let mut rt = MockRuntime::new();
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let params = init_params(rt.meta(), 4);
        let (x, y, _) = toy_batch(&mut rng, 128);
        let ev = rt.eval_step(&params, &x, &y).unwrap();
        // Train step with lr=0 on the same 128 wouldn't be allowed
        // (mbs 128 is compiled), so compare against forward directly.
        let mut probs = Vec::new();
        let (loss, correct) = MockRuntime::forward_into(&params, &x, &y, 128, &mut probs);
        assert_eq!(ev.loss, loss);
        assert_eq!(ev.correct, correct);
    }

    #[test]
    fn in_place_step_bit_identical_to_allocating_step() {
        // The pooled fast path and the allocating seed path must agree
        // bit-for-bit on every backend — over multiple chained steps so
        // divergence would compound and be caught.
        for backend in [Backend::Scalar, Backend::Simd] {
            with_backend(backend, || {
                let mut rt_a = MockRuntime::new();
                let mut rt_b = MockRuntime::new();
                let mut rng = Xoshiro256pp::seed_from_u64(9);
                let init = init_params(rt_a.meta(), 5);
                // Seed path state.
                let mut p_a = init.clone();
                let mut m_a = ParamVec::zeros_like(&init);
                // Fast path state (updated in place).
                let mut p_b = init.clone();
                let mut m_b = ParamVec::zeros_like(&init);
                let mut grad = ParamVec::default();
                for _ in 0..10 {
                    let (x, y, _) = toy_batch(&mut rng, 16);
                    let out = rt_a
                        .train_step(&p_a, &m_a, &x, &y, 16, 0.4, 0.9)
                        .unwrap();
                    p_a = out.params;
                    m_a = out.momentum;
                    let st = rt_b
                        .train_step_in_place(&mut p_b, &mut m_b, &mut grad, &x, &y, 16, 0.4, 0.9)
                        .unwrap();
                    assert_eq!(st.loss.to_bits(), out.loss.to_bits());
                    assert_eq!(st.correct.to_bits(), out.correct.to_bits());
                    for (ta, tb) in p_a.tensors.iter().zip(&p_b.tensors) {
                        for (a, b) in ta.data().iter().zip(tb.data()) {
                            assert_eq!(a.to_bits(), b.to_bits());
                        }
                    }
                    for (ta, tb) in m_a.tensors.iter().zip(&m_b.tensors) {
                        for (a, b) in ta.data().iter().zip(tb.data()) {
                            assert_eq!(a.to_bits(), b.to_bits());
                        }
                    }
                }
            });
        }
    }

    #[test]
    fn rejects_malformed_batches() {
        let mut rt = MockRuntime::new();
        let params = init_params(rt.meta(), 1);
        let mom = ParamVec::zeros_like(&params);
        assert!(rt
            .train_step(&params, &mom, &[0.0; 10], &[0; 2], 2, 0.1, 0.0)
            .is_err());
        let mut p = params.clone();
        let mut m = mom.clone();
        let mut g = ParamVec::default();
        assert!(rt
            .train_step_in_place(&mut p, &mut m, &mut g, &[0.0; 10], &[0; 2], 2, 0.1, 0.0)
            .is_err());
        assert!(rt.eval_step(&params, &[0.0; 10], &[0; 2]).is_err());
    }
}
