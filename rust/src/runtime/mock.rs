//! Artifact-free [`ModelRuntime`]: a softmax regression with real,
//! host-computed gradients.
//!
//! The coordinator's behaviour (gating, aggregation, allocation,
//! scheduling) is independent of *which* differentiable model produces
//! the losses, so every coordinator test and micro-bench runs against
//! this runtime: real learning dynamics, zero XLA dependency, ~µs per
//! step.  The input is a flattened (4, 4, 2) "image" (32 features, 10
//! classes ⇒ 330 parameters).

use anyhow::{bail, Result};

use super::manifest::ModelMeta;
use super::{EvalOut, ModelRuntime, TrainOut};
use crate::tensor::{ParamVec, Tensor};

pub const MOCK_FEATURES: usize = 32;
pub const MOCK_CLASSES: usize = 10;

#[derive(Debug, Clone)]
pub struct MockRuntime {
    meta: ModelMeta,
    execs: u64,
}

impl Default for MockRuntime {
    fn default() -> Self {
        Self::new()
    }
}

impl MockRuntime {
    pub fn new() -> Self {
        MockRuntime {
            meta: ModelMeta {
                name: "mock".into(),
                input_shape: (4, 4, 2),
                num_classes: MOCK_CLASSES,
                param_shapes: vec![
                    vec![MOCK_FEATURES, MOCK_CLASSES],
                    vec![MOCK_CLASSES],
                ],
                param_count: MOCK_FEATURES * MOCK_CLASSES + MOCK_CLASSES,
                train_batches: vec![2, 4, 8, 16, 32, 64, 128, 256],
                eval_batch: 128,
            },
            execs: 0,
        }
    }

    /// logits[b] = x[b]·W + bias; returns (mean xent loss, #correct,
    /// per-class probabilities for the gradient).
    fn forward(
        params: &ParamVec,
        x: &[f32],
        y: &[i32],
        batch: usize,
    ) -> (f32, f32, Vec<f32>) {
        let w = params.tensors[0].data();
        let b = params.tensors[1].data();
        let mut probs = vec![0f32; batch * MOCK_CLASSES];
        let mut loss = 0f64;
        let mut correct = 0f32;
        for i in 0..batch {
            let xi = &x[i * MOCK_FEATURES..(i + 1) * MOCK_FEATURES];
            let row = &mut probs[i * MOCK_CLASSES..(i + 1) * MOCK_CLASSES];
            for (c, r) in row.iter_mut().enumerate() {
                let mut z = b[c];
                for (f, &xv) in xi.iter().enumerate() {
                    z += xv * w[f * MOCK_CLASSES + c];
                }
                *r = z;
            }
            // softmax + xent
            let max = row.iter().cloned().fold(f32::MIN, f32::max);
            let mut denom = 0f32;
            for r in row.iter_mut() {
                *r = (*r - max).exp();
                denom += *r;
            }
            for r in row.iter_mut() {
                *r /= denom;
            }
            let mut argmax = 0usize;
            for c in 1..MOCK_CLASSES {
                if row[c] > row[argmax] {
                    argmax = c;
                }
            }
            let label = y[i] as usize;
            loss -= (row[label].max(1e-12) as f64).ln();
            if argmax == label {
                correct += 1.0;
            }
        }
        ((loss / batch as f64) as f32, correct, probs)
    }
}

impl ModelRuntime for MockRuntime {
    fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn train_step(
        &mut self,
        params: &ParamVec,
        momentum: &ParamVec,
        x: &[f32],
        y: &[i32],
        mbs: usize,
        lr: f32,
        mu: f32,
    ) -> Result<TrainOut> {
        if x.len() != mbs * MOCK_FEATURES || y.len() != mbs {
            bail!("mock: bad batch ({} x, {} y, mbs {mbs})", x.len(), y.len());
        }
        self.execs += 1;
        let (loss, correct, probs) = Self::forward(params, x, y, mbs);

        // grad_logits = probs − one_hot(y), averaged over the batch.
        let w = params.tensors[0].data();
        let b = params.tensors[1].data();
        let mut gw = vec![0f32; w.len()];
        let mut gb = vec![0f32; b.len()];
        let inv = 1.0 / mbs as f32;
        for i in 0..mbs {
            let xi = &x[i * MOCK_FEATURES..(i + 1) * MOCK_FEATURES];
            for c in 0..MOCK_CLASSES {
                let mut g = probs[i * MOCK_CLASSES + c];
                if y[i] as usize == c {
                    g -= 1.0;
                }
                g *= inv;
                gb[c] += g;
                for (f, &xv) in xi.iter().enumerate() {
                    gw[f * MOCK_CLASSES + c] += g * xv;
                }
            }
        }

        // SGD with momentum, matching the L2 train step semantics.
        let mw = momentum.tensors[0].data();
        let mb = momentum.tensors[1].data();
        let new_mw: Vec<f32> =
            mw.iter().zip(&gw).map(|(m, g)| mu * m + g).collect();
        let new_mb: Vec<f32> =
            mb.iter().zip(&gb).map(|(m, g)| mu * m + g).collect();
        let new_w: Vec<f32> =
            w.iter().zip(&new_mw).map(|(p, v)| p - lr * v).collect();
        let new_b: Vec<f32> =
            b.iter().zip(&new_mb).map(|(p, v)| p - lr * v).collect();

        Ok(TrainOut {
            params: ParamVec {
                tensors: vec![
                    Tensor::new(vec![MOCK_FEATURES, MOCK_CLASSES], new_w),
                    Tensor::new(vec![MOCK_CLASSES], new_b),
                ],
            },
            momentum: ParamVec {
                tensors: vec![
                    Tensor::new(vec![MOCK_FEATURES, MOCK_CLASSES], new_mw),
                    Tensor::new(vec![MOCK_CLASSES], new_mb),
                ],
            },
            loss,
            correct,
        })
    }

    fn eval_step(&mut self, params: &ParamVec, x: &[f32], y: &[i32]) -> Result<EvalOut> {
        let b = self.meta.eval_batch;
        if x.len() != b * MOCK_FEATURES || y.len() != b {
            bail!("mock: bad eval batch");
        }
        self.execs += 1;
        let (loss, correct, _) = Self::forward(params, x, y, b);
        Ok(EvalOut { loss, correct })
    }

    fn exec_count(&self) -> u64 {
        self.execs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::init_params;
    use crate::util::rng::Xoshiro256pp;

    /// Linearly separable toy data: class templates + noise.
    fn toy_batch(
        rng: &mut Xoshiro256pp,
        n: usize,
    ) -> (Vec<f32>, Vec<i32>, [[f32; MOCK_FEATURES]; MOCK_CLASSES]) {
        let mut templates = [[0f32; MOCK_FEATURES]; MOCK_CLASSES];
        let mut trng = Xoshiro256pp::seed_from_u64(99);
        for t in templates.iter_mut() {
            for v in t.iter_mut() {
                *v = trng.normal() as f32;
            }
        }
        let mut x = Vec::with_capacity(n * MOCK_FEATURES);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let c = rng.next_below(MOCK_CLASSES as u64) as usize;
            y.push(c as i32);
            for f in 0..MOCK_FEATURES {
                x.push(templates[c][f] + 0.3 * rng.normal() as f32);
            }
        }
        (x, y, templates)
    }

    #[test]
    fn mock_learns_separable_data() {
        let mut rt = MockRuntime::new();
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let mut params = init_params(rt.meta(), 1);
        let mut mom = ParamVec::zeros_like(&params);
        let mut first = 0f32;
        let mut last = 0f32;
        for step in 0..60 {
            let (x, y, _) = toy_batch(&mut rng, 16);
            let out = rt
                .train_step(&params, &mom, &x, &y, 16, 0.5, 0.0)
                .unwrap();
            params = out.params;
            mom = out.momentum;
            if step == 0 {
                first = out.loss;
            }
            last = out.loss;
        }
        assert!(last < first * 0.3, "no learning: {first} → {last}");
        assert_eq!(rt.exec_count(), 60);
    }

    #[test]
    fn zero_lr_is_identity() {
        let mut rt = MockRuntime::new();
        let mut rng = Xoshiro256pp::seed_from_u64(6);
        let params = init_params(rt.meta(), 2);
        let mom = ParamVec::zeros_like(&params);
        let (x, y, _) = toy_batch(&mut rng, 8);
        let out = rt.train_step(&params, &mom, &x, &y, 8, 0.0, 0.0).unwrap();
        assert_eq!(out.params, params);
    }

    #[test]
    fn momentum_zero_buffers_carry_raw_gradient() {
        // Mirrors the L2 pytest: new_p = p − lr·g when mu = 0.
        let mut rt = MockRuntime::new();
        let mut rng = Xoshiro256pp::seed_from_u64(7);
        let params = init_params(rt.meta(), 3);
        let mom = ParamVec::zeros_like(&params);
        let (x, y, _) = toy_batch(&mut rng, 4);
        let lr = 0.1f32;
        let out = rt.train_step(&params, &mom, &x, &y, 4, lr, 0.0).unwrap();
        for ((p_new, p_old), g) in out
            .params
            .tensors
            .iter()
            .zip(&params.tensors)
            .zip(&out.momentum.tensors)
        {
            for ((a, b), gv) in
                p_new.data().iter().zip(b_iter(p_old)).zip(g.data())
            {
                assert!((a - (b - lr * gv)).abs() < 1e-6);
            }
        }
        fn b_iter(t: &Tensor) -> std::slice::Iter<'_, f32> {
            t.data().iter()
        }
    }

    #[test]
    fn eval_matches_train_loss_on_same_batch() {
        let mut rt = MockRuntime::new();
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        let params = init_params(rt.meta(), 4);
        let (x, y, _) = toy_batch(&mut rng, 128);
        let ev = rt.eval_step(&params, &x, &y).unwrap();
        // Train step with lr=0 on the same 128 wouldn't be allowed
        // (mbs 128 is compiled), so compare against forward directly.
        let (loss, correct, _) = MockRuntime::forward(&params, &x, &y, 128);
        assert_eq!(ev.loss, loss);
        assert_eq!(ev.correct, correct);
    }

    #[test]
    fn rejects_malformed_batches() {
        let mut rt = MockRuntime::new();
        let params = init_params(rt.meta(), 1);
        let mom = ParamVec::zeros_like(&params);
        assert!(rt
            .train_step(&params, &mom, &[0.0; 10], &[0; 2], 2, 0.1, 0.0)
            .is_err());
        assert!(rt.eval_step(&params, &[0.0; 10], &[0; 2]).is_err());
    }
}
