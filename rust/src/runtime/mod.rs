//! Model execution runtime.
//!
//! [`ModelRuntime`] is the seam between the coordinator (L3) and the
//! AOT-compiled compute (L2/L1): a train step is "params + batch in,
//! params + loss out", nothing more.  Two implementations:
//!
//! * [`XlaRuntime`] — loads `artifacts/*.hlo.txt` through the `xla`
//!   crate (PJRT CPU client), compiles once per (kind, batch size), and
//!   executes on the hot path.  This is the production path; Python is
//!   never involved.
//! * [`MockRuntime`] — a host-computed softmax regression with real
//!   gradients.  Same trait, no artifacts needed: coordinator tests,
//!   property tests and micro-benches run against it.

pub mod manifest;
pub mod mock;
// The PJRT path needs the external `xla` crate, which has no offline
// registry; without the `xla` cargo feature a stub with the identical
// public surface compiles instead, and artifact-backed paths degrade
// to clean runtime errors / test skips (the mock runtime covers all
// coordinator logic).
#[cfg(feature = "xla")]
pub mod xla_rt;
#[cfg(not(feature = "xla"))]
#[path = "xla_stub.rs"]
pub mod xla_rt;

pub use manifest::{Manifest, ModelArtifacts, ModelMeta};
pub use mock::MockRuntime;
pub use xla_rt::XlaRuntime;

use anyhow::Result;

use crate::tensor::ParamVec;
use crate::util::salts;

/// Output of one fused fwd+bwd+update step.
#[derive(Debug, Clone)]
pub struct TrainOut {
    pub params: ParamVec,
    pub momentum: ParamVec,
    pub loss: f32,
    pub correct: f32,
}

/// Output of one eval pass over a probe batch.
#[derive(Debug, Clone, Copy)]
pub struct EvalOut {
    pub loss: f32,
    pub correct: f32,
}

/// The L3 ↔ L2 execution seam.
///
/// Not `Send`: the PJRT client wrapper is `Rc`-based, so each live-mode
/// thread constructs its own runtime instead of sharing one.
pub trait ModelRuntime {
    fn meta(&self) -> &ModelMeta;

    /// One mini-batch fwd+bwd+SGD(M) step.  `x` is `mbs·H·W·C` floats,
    /// `y` is `mbs` labels; `mbs` must be a compiled batch size
    /// (callers use [`ModelMeta::clamp_train_batch`]).
    fn train_step(
        &mut self,
        params: &ParamVec,
        momentum: &ParamVec,
        x: &[f32],
        y: &[i32],
        mbs: usize,
        lr: f32,
        mu: f32,
    ) -> Result<TrainOut>;

    /// In-place train step — the worker fast path (DESIGN.md §13):
    /// `params`/`momentum` are updated in place and `grad_scratch` (a
    /// pool-leased buffer shaped like the params) absorbs the gradient
    /// accumulation, so a steady-state step performs zero heap
    /// allocations when the runtime supports it.
    ///
    /// The default implementation is the *allocating seed path*: it
    /// delegates to [`ModelRuntime::train_step`] and copies the fresh
    /// buffers back — bit-identical results by construction (the
    /// property tests in `tests/coordinator_props.rs` pin this), just
    /// slower.  Runtimes with a native in-place step (the mock)
    /// override it.
    #[allow(clippy::too_many_arguments)]
    fn train_step_in_place(
        &mut self,
        params: &mut ParamVec,
        momentum: &mut ParamVec,
        grad_scratch: &mut ParamVec,
        x: &[f32],
        y: &[i32],
        mbs: usize,
        lr: f32,
        mu: f32,
    ) -> Result<EvalOut> {
        let _ = grad_scratch;
        let out = self.train_step(params, momentum, x, y, mbs, lr, mu)?;
        params.copy_from(&out.params);
        momentum.copy_from(&out.momentum);
        Ok(EvalOut { loss: out.loss, correct: out.correct })
    }

    /// Evaluate on one probe batch of exactly `meta().eval_batch`
    /// samples; returns mean loss and #correct.
    fn eval_step(&mut self, params: &ParamVec, x: &[f32], y: &[i32]) -> Result<EvalOut>;

    /// Number of executions performed (for perf accounting).
    fn exec_count(&self) -> u64;
}

/// He-normal initialization on the host, mirroring
/// `python/compile/model.py::init_params` in spirit (weights
/// N(0, √(2/fan_in)), biases zero).  Exact bitwise agreement with the
/// jax init is not required — the golden fixture carries its own
/// parameters.
pub fn init_params(meta: &ModelMeta, seed: u64) -> ParamVec {
    use crate::tensor::Tensor;
    use crate::util::rng::Xoshiro256pp;
    let mut rng = Xoshiro256pp::stream(seed, salts::INIT_PARAMS);
    let mut tensors = Vec::with_capacity(meta.param_shapes.len());
    for shape in &meta.param_shapes {
        if shape.len() == 1 {
            tensors.push(Tensor::zeros(shape.clone())); // bias
        } else {
            let fan_in: usize = shape[..shape.len() - 1].iter().product();
            let std = (2.0 / fan_in as f64).sqrt();
            let n: usize = shape.iter().product();
            let data: Vec<f32> =
                (0..n).map(|_| (rng.normal() * std) as f32).collect();
            tensors.push(Tensor::new(shape.clone(), data));
        }
    }
    ParamVec { tensors }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_meta() -> ModelMeta {
        ModelMeta {
            name: "tiny".into(),
            input_shape: (2, 2, 1),
            num_classes: 3,
            param_shapes: vec![vec![4, 3], vec![3]],
            param_count: 15,
            train_batches: vec![8],
            eval_batch: 16,
        }
    }

    #[test]
    fn init_params_shapes_and_stats() {
        let meta = tiny_meta();
        let p = init_params(&meta, 7);
        assert_eq!(p.tensors.len(), 2);
        assert_eq!(p.tensors[0].shape(), &[4, 3]);
        // Bias is zero.
        assert!(p.tensors[1].data().iter().all(|&x| x == 0.0));
        assert!(p.tensors[0].data().iter().any(|&x| x != 0.0));
        // Deterministic per seed.
        assert_eq!(init_params(&meta, 7), p);
        assert_ne!(init_params(&meta, 8), p);
    }

    #[test]
    fn init_params_weight_std_matches_he() {
        let meta = ModelMeta {
            name: "wide".into(),
            input_shape: (1, 1, 1),
            num_classes: 2,
            param_shapes: vec![vec![1000, 50], vec![50]],
            param_count: 50_050,
            train_batches: vec![8],
            eval_batch: 8,
        };
        let p = init_params(&meta, 3);
        let w = p.tensors[0].data();
        let mean: f64 = w.iter().map(|&x| x as f64).sum::<f64>() / w.len() as f64;
        let var: f64 =
            w.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / w.len() as f64;
        let want = 2.0 / 1000.0;
        assert!((var - want).abs() < want * 0.1, "var {var} want {want}");
    }
}
