//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime.  Parses `artifacts/manifest.json` into typed
//! metadata (model shapes, compiled batch sizes, artifact paths).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Static description of one compiled model.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelMeta {
    pub name: String,
    /// (H, W, C) of one input sample.
    pub input_shape: (usize, usize, usize),
    pub num_classes: usize,
    /// Interleaved [w0, b0, w1, b1, …] shapes in artifact order.
    pub param_shapes: Vec<Vec<usize>>,
    pub param_count: usize,
    /// Mini-batch sizes with a compiled train-step executable.
    pub train_batches: Vec<usize>,
    /// Batch size of the compiled eval executable.
    pub eval_batch: usize,
}

impl ModelMeta {
    pub fn input_elems(&self) -> usize {
        self.input_shape.0 * self.input_shape.1 * self.input_shape.2
    }

    /// Closest compiled train batch ≤ requested (or the smallest one) —
    /// how the dual binary search's MBS domain maps onto the finite
    /// artifact set (DESIGN.md §3).
    pub fn clamp_train_batch(&self, mbs: usize) -> usize {
        let mut best = self.train_batches[0];
        for &b in &self.train_batches {
            if b <= mbs && b > best {
                best = b;
            }
        }
        best
    }
}

/// Paths of every artifact for one model.
#[derive(Debug, Clone)]
pub struct ModelArtifacts {
    pub meta: ModelMeta,
    pub train_paths: BTreeMap<usize, PathBuf>,
    pub eval_path: PathBuf,
    pub golden: Option<GoldenPaths>,
}

#[derive(Debug, Clone)]
pub struct GoldenPaths {
    pub index: PathBuf,
    pub blob: PathBuf,
}

/// The whole artifacts directory.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelArtifacts>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        if j.at("format").and_then(Json::as_u64) != Some(1) {
            bail!("unsupported manifest format");
        }
        let mut models = BTreeMap::new();
        let model_obj = j
            .at("models")
            .and_then(Json::as_obj)
            .ok_or_else(|| anyhow!("manifest missing 'models'"))?;
        for (name, m) in model_obj {
            let shape_arr = m
                .get("input_shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: input_shape"))?;
            if shape_arr.len() != 3 {
                bail!("{name}: input_shape must be rank 3");
            }
            let dim = |i: usize| -> Result<usize> {
                shape_arr[i]
                    .as_usize()
                    .ok_or_else(|| anyhow!("{name}: bad input dim"))
            };
            let param_shapes: Vec<Vec<usize>> = m
                .get("param_shapes")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("{name}: param_shapes"))?
                .iter()
                .map(|s| {
                    s.as_arr()
                        .ok_or_else(|| anyhow!("{name}: bad shape"))?
                        .iter()
                        .map(|d| d.as_usize().ok_or_else(|| anyhow!("bad dim")))
                        .collect::<Result<Vec<usize>>>()
                })
                .collect::<Result<Vec<_>>>()?;

            let mut train_paths = BTreeMap::new();
            for (batch, info) in m
                .get("train")
                .and_then(Json::as_obj)
                .ok_or_else(|| anyhow!("{name}: train"))?
            {
                let b: usize = batch.parse().context("train batch key")?;
                let p = info
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("{name}: train path"))?;
                train_paths.insert(b, dir.join(p));
            }
            if train_paths.is_empty() {
                bail!("{name}: no train artifacts");
            }

            let evals = m
                .get("eval")
                .and_then(Json::as_obj)
                .ok_or_else(|| anyhow!("{name}: eval"))?;
            let (eval_batch, eval_info) = evals
                .iter()
                .next()
                .ok_or_else(|| anyhow!("{name}: no eval artifact"))?;
            let eval_path = dir.join(
                eval_info
                    .get("path")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("{name}: eval path"))?,
            );

            let golden = m.get("golden").and_then(Json::as_obj).map(|g| GoldenPaths {
                index: dir.join(g.get("index").and_then(Json::as_str).unwrap_or_default()),
                blob: dir.join(g.get("blob").and_then(Json::as_str).unwrap_or_default()),
            });

            let meta = ModelMeta {
                name: name.clone(),
                input_shape: (dim(0)?, dim(1)?, dim(2)?),
                num_classes: m
                    .get("num_classes")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("{name}: num_classes"))?,
                param_count: m
                    .get("param_count")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| anyhow!("{name}: param_count"))?,
                param_shapes,
                train_batches: train_paths.keys().copied().collect(),
                eval_batch: eval_batch.parse().context("eval batch key")?,
            };
            // Cross-check: declared count must equal the shape product sum.
            let computed: usize = meta
                .param_shapes
                .iter()
                .map(|s| s.iter().product::<usize>())
                .sum();
            if computed != meta.param_count {
                bail!(
                    "{name}: param_count {} != computed {computed}",
                    meta.param_count
                );
            }
            models.insert(
                name.clone(),
                ModelArtifacts { meta, train_paths, eval_path, golden },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    pub fn model(&self, name: &str) -> Result<&ModelArtifacts> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow!("model '{name}' not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": 1,
      "eval_batch": 128,
      "models": {
        "cnn": {
          "input_shape": [28, 28, 1],
          "num_classes": 10,
          "param_count": 26,
          "param_shapes": [[2, 3], [3], [3, 5], [2]],
          "train": {"16": {"path": "cnn_train_b16.hlo.txt", "bytes": 1, "sha256_16": "x"},
                     "8": {"path": "cnn_train_b8.hlo.txt", "bytes": 1, "sha256_16": "x"}},
          "eval": {"128": {"path": "cnn_eval_b128.hlo.txt", "bytes": 1, "sha256_16": "x"}}
        }
      }
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let m = Manifest::parse(Path::new("/tmp/a"), SAMPLE).unwrap();
        let cnn = m.model("cnn").unwrap();
        assert_eq!(cnn.meta.input_shape, (28, 28, 1));
        assert_eq!(cnn.meta.train_batches, vec![8, 16]);
        assert_eq!(cnn.meta.eval_batch, 128);
        assert_eq!(
            cnn.train_paths[&16],
            PathBuf::from("/tmp/a/cnn_train_b16.hlo.txt")
        );
        assert!(m.model("nope").is_err());
    }

    #[test]
    fn rejects_param_count_mismatch() {
        let bad = SAMPLE.replace("\"param_count\": 26", "\"param_count\": 99");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn rejects_wrong_format_version() {
        let bad = SAMPLE.replace("\"format\": 1", "\"format\": 2");
        assert!(Manifest::parse(Path::new("/tmp"), &bad).is_err());
    }

    #[test]
    fn clamp_train_batch_maps_search_domain_onto_artifacts() {
        let m = Manifest::parse(Path::new("/tmp"), SAMPLE).unwrap();
        let meta = &m.model("cnn").unwrap().meta;
        assert_eq!(meta.clamp_train_batch(2), 8); // below smallest → smallest
        assert_eq!(meta.clamp_train_batch(8), 8);
        assert_eq!(meta.clamp_train_batch(12), 8);
        assert_eq!(meta.clamp_train_batch(16), 16);
        assert_eq!(meta.clamp_train_batch(256), 16); // above largest → largest
    }

    #[test]
    fn loads_real_artifacts_dir_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return; // artifacts not built in this checkout
        }
        let m = Manifest::load(&dir).unwrap();
        let cnn = &m.model("cnn").unwrap().meta;
        assert_eq!(cnn.param_count, 109_378);
        let alex = &m.model("alexnet").unwrap().meta;
        assert_eq!(alex.param_count, 995_046);
        for art in m.models.values() {
            for p in art.train_paths.values() {
                assert!(p.exists(), "{}", p.display());
            }
            assert!(art.eval_path.exists());
        }
    }
}
