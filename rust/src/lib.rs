//! # hermes-dml
//!
//! A production-grade reproduction of **Hermes** — *"When Less is More:
//! Achieving Faster Convergence in Distributed Edge Machine Learning"*
//! (HiPC 2024) — as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordination contribution: the parameter
//!   server, the HermesGUP gradient-push gate, loss-based SGD
//!   aggregation, dual-binary-search dataset allocation, and the
//!   BSP/ASP/SSP/EBSP/SelSync baselines, all over a deterministic
//!   discrete-event cluster simulator plus a live threaded TCP mode.
//! * **L2/L1 (build time)** — JAX models whose dense/conv compute is
//!   Pallas kernels, AOT-lowered to HLO text and executed here through
//!   the XLA PJRT CPU client ([`runtime`]).  Python never runs on the
//!   request path.
//!
//! See DESIGN.md for the system inventory and the per-experiment index,
//! and EXPERIMENTS.md for paper-vs-measured results.

pub mod alloc;
pub mod bench_harness;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod data;
pub mod exp;
pub mod frameworks;
pub mod gup;
pub mod live;
pub mod metrics;
pub mod model;
pub mod net;
pub mod ps;
pub mod runtime;
pub mod sim;
pub mod tensor;
pub mod util;
pub mod wire;
pub mod worker;
