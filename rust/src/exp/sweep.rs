//! Scoped-thread parallel sweep runner: one deterministic DES instance
//! per seed×framework job, fanned out over the machine's cores
//! (std-only — `std::thread::scope`, no rayon offline).
//!
//! **Streaming (DESIGN.md §13).**  The engine is a bounded-memory
//! streaming runner: workers pull jobs from a shared index and deposit
//! finished [`RunMetrics`] into a reorder buffer of at most `window`
//! rows; the calling thread drains that buffer **in job order** into a
//! caller-supplied sink (an incremental CSV/JSON writer, a collector, a
//! progress printer).  A worker may only claim job `i` once
//! `i < emitted + window`, so at no point are more than `window` result
//! rows resident — a 10 000-job grid streams through a handful of rows
//! instead of holding every loss curve in memory.  [`run_sweep`] is the
//! collect-all convenience wrapper (window = job count).
//!
//! Determinism: every job is a pure function of its [`RunConfig`] — it
//! owns a private runtime, RNG streams, event queue and metrics — so
//! running jobs concurrently and delivering results by job index is
//! **bit-identical** to running them sequentially (asserted by
//! `parallel_sweep_matches_sequential_bitwise` below).  Only
//! `sim_wall_time` (real wall clock) differs between schedules.
//!
//! Runtimes are constructed *inside* the worker thread via the
//! `make_rt` factory because [`ModelRuntime`] boxes are deliberately
//! not `Send` (the PJRT client wrapper is `Rc`-based); each thread owns
//! its runtime end to end.

use std::collections::BTreeMap;
use std::sync::{Condvar, Mutex};

use anyhow::Result;

use crate::config::RunConfig;
use crate::frameworks::run_framework_opts;
use crate::metrics::RunMetrics;
use crate::runtime::ModelRuntime;

/// One unit of a sweep: a labelled run configuration.
pub struct SweepJob {
    /// Reported as `RunMetrics::framework` in the result row.
    pub label: String,
    pub cfg: RunConfig,
    /// Record Fig. 1-style timeline segments (costs memory; off for
    /// table sweeps).
    pub record_timeline: bool,
}

impl SweepJob {
    pub fn new(label: impl Into<String>, cfg: RunConfig) -> SweepJob {
        SweepJob { label: label.into(), cfg, record_timeline: false }
    }
}

/// Default worker-thread count for `jobs` parallel jobs: one per
/// available core, capped at the job count.
pub fn default_threads(jobs: usize) -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, jobs.max(1))
}

/// Default reorder-buffer bound for a streaming sweep: enough slack
/// that no worker stalls on an in-order sink in the common case, still
/// O(threads) memory.
pub fn default_window(threads: usize) -> usize {
    threads.max(1) * 2
}

/// What a streaming sweep observed about itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SweepStats {
    /// Rows delivered to the sink.
    pub jobs: usize,
    /// High-water mark of finished-but-not-yet-emitted rows — the
    /// actual peak result residency (≤ the requested window).
    pub peak_buffered: usize,
}

/// Shared state of one streaming run (behind a mutex, signalled by a
/// condvar): the claim cursor, the emit cursor, and the reorder buffer.
struct Reorder {
    /// Next unclaimed job index.
    next: usize,
    /// Rows already handed to the sink (all indices < emitted).
    emitted: usize,
    /// Finished jobs awaiting their in-order turn.
    done: BTreeMap<usize, Result<RunMetrics>>,
    peak: usize,
    /// Set on sink error / first job error: workers stop claiming.
    stop: bool,
}

/// Run every job, delivering results **in job order** to `sink` while
/// holding at most `window` finished rows in memory.
///
/// `threads == 0` means one per core ([`default_threads`]) and
/// `window == 0` means [`default_window`] of the resolved thread count
/// — resolved *here* so every caller shares one contract.
/// `threads == 1` is the sequential reference path; anything larger
/// fans jobs out over scoped threads pulling from a shared work index.
/// The first error in job order — whether from a job or from the sink —
/// stops the sweep (in-flight jobs finish, nothing new is claimed) and
/// is returned.
pub fn run_sweep_streaming<F, S>(
    jobs: &[SweepJob],
    threads: usize,
    window: usize,
    make_rt: F,
    mut sink: S,
) -> Result<SweepStats>
where
    F: Fn(&SweepJob) -> Result<Box<dyn ModelRuntime>> + Sync,
    S: FnMut(usize, RunMetrics) -> Result<()>,
{
    let n = jobs.len();
    if n == 0 {
        return Ok(SweepStats { jobs: 0, peak_buffered: 0 });
    }
    let threads = if threads == 0 { default_threads(n) } else { threads }.clamp(1, n);
    let window = if window == 0 { default_window(threads) } else { window };
    let run_one = move |job: &SweepJob| -> Result<RunMetrics> {
        let rt = make_rt(job)?;
        let exec = || run_framework_opts(job.cfg.clone(), rt, job.record_timeline);
        // A parallel sweep already saturates the cores with job-level
        // parallelism; letting every tensor op inside a job fan out
        // over `tensor::shards` workers on top of that would
        // oversubscribe (threads × shards) and pay a scoped-spawn per
        // kernel call.  Worker threads therefore pin the shard layer to
        // inline execution — bit-identical either way (DESIGN.md §12),
        // so the sequential-vs-parallel equality below is unaffected.
        let mut run = if threads > 1 {
            crate::tensor::shards::with_shards(1, exec)?
        } else {
            exec()?
        };
        run.framework = job.label.clone();
        Ok(run)
    };

    if threads == 1 {
        for (i, job) in jobs.iter().enumerate() {
            sink(i, run_one(job)?)?;
        }
        return Ok(SweepStats { jobs: n, peak_buffered: 1 });
    }

    let state = Mutex::new(Reorder {
        next: 0,
        emitted: 0,
        done: BTreeMap::new(),
        peak: 0,
        stop: false,
    });
    let cv = Condvar::new();
    let state_ref = &state;
    let cv_ref = &cv;
    let run_one = &run_one;
    let mut first_err: Option<anyhow::Error> = None;
    let mut emitted_rows = 0usize;

    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(move || loop {
                // Claim the next job, but never run more than `window`
                // ahead of the sink — that bound is what makes the
                // reorder buffer (and thus result residency) O(window).
                let i = {
                    let mut g = state_ref.lock().unwrap();
                    loop {
                        if g.stop || g.next >= n {
                            return;
                        }
                        if g.next < g.emitted + window {
                            let i = g.next;
                            g.next += 1;
                            break i;
                        }
                        g = cv_ref.wait(g).unwrap();
                    }
                };
                // A panicking job must still produce a row — otherwise
                // the sink would wait on this index forever and the
                // panic would only surface at scope join.
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                    || run_one(&jobs[i]),
                ))
                .unwrap_or_else(|_| Err(anyhow::anyhow!("sweep job {i} panicked")));
                let mut g = state_ref.lock().unwrap();
                g.done.insert(i, res);
                g.peak = g.peak.max(g.done.len());
                cv_ref.notify_all();
            });
        }

        // The calling thread is the sink: drain the reorder buffer in
        // job order, unlocking while each row is written.
        let mut g = state.lock().unwrap();
        while g.emitted < n {
            let idx = g.emitted;
            if let Some(res) = g.done.remove(&idx) {
                g.emitted += 1;
                cv.notify_all();
                drop(g);
                let row = match res {
                    Ok(m) => {
                        let r = sink(idx, m);
                        if r.is_ok() {
                            emitted_rows += 1;
                        }
                        r
                    }
                    Err(e) => Err(e),
                };
                g = state.lock().unwrap();
                if let Err(e) = row {
                    first_err = Some(e);
                    g.stop = true;
                    cv.notify_all();
                    break;
                }
            } else {
                g = cv.wait(g).unwrap();
            }
        }
        g.stop = true;
        drop(g);
        cv.notify_all();
        // Leaving the scope joins the workers: each finishes its
        // in-flight job, sees `stop`, and exits.
    });

    if let Some(e) = first_err {
        return Err(e);
    }
    let peak = state.into_inner().unwrap().peak;
    Ok(SweepStats { jobs: emitted_rows, peak_buffered: peak })
}

/// Run every job and return results in job order — the collect-all
/// wrapper over [`run_sweep_streaming`] (window = job count, so workers
/// are never throttled; identical scheduling freedom to the original
/// collect-all runner, bit-identical results either way).
/// `threads == 0` means one per core.
pub fn run_sweep<F>(jobs: Vec<SweepJob>, threads: usize, make_rt: F) -> Result<Vec<RunMetrics>>
where
    F: Fn(&SweepJob) -> Result<Box<dyn ModelRuntime>> + Sync,
{
    let mut out: Vec<Option<RunMetrics>> = Vec::new();
    out.resize_with(jobs.len(), || None);
    run_sweep_streaming(&jobs, threads, jobs.len().max(1), make_rt, |i, m| {
        out[i] = Some(m);
        Ok(())
    })?;
    Ok(out
        .into_iter()
        .map(|slot| slot.expect("sweep job not executed"))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockRuntime;

    fn jobs() -> Vec<SweepJob> {
        crate::frameworks::PRESETS
            .iter()
            .map(|fw| {
                let mut cfg = crate::exp::scaled_cfg("mock", fw);
                cfg.max_iters = 120;
                cfg.target_acc = 0.88;
                SweepJob::new(*fw, cfg)
            })
            .collect()
    }

    fn mock_rt(_job: &SweepJob) -> Result<Box<dyn ModelRuntime>> {
        Ok(Box::new(MockRuntime::new()))
    }

    #[test]
    fn parallel_sweep_matches_sequential_bitwise() {
        let seq = run_sweep(jobs(), 1, mock_rt).unwrap();
        let par = run_sweep(jobs(), 4, mock_rt).unwrap();
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.framework, b.framework);
            assert_eq!(a.iterations, b.iterations, "{}", a.framework);
            assert_eq!(
                a.virtual_time.to_bits(),
                b.virtual_time.to_bits(),
                "{}",
                a.framework
            );
            assert_eq!(
                a.final_accuracy.to_bits(),
                b.final_accuracy.to_bits(),
                "{}",
                a.framework
            );
            assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits());
            assert_eq!(a.api_calls, b.api_calls, "{}", a.framework);
            assert_eq!(a.bytes, b.bytes, "{}", a.framework);
            assert_eq!(a.global_updates, b.global_updates);
            assert_eq!(a.curve, b.curve, "{}", a.framework);
            assert_eq!(a.converged, b.converged);
        }
    }

    #[test]
    fn results_preserve_job_order_and_labels() {
        let out = run_sweep(jobs(), 3, mock_rt).unwrap();
        let labels: Vec<&str> = out.iter().map(|r| r.framework.as_str()).collect();
        assert_eq!(labels, crate::frameworks::PRESETS.to_vec());
    }

    #[test]
    fn streaming_sink_sees_rows_in_order_with_bounded_buffer() {
        let js = jobs();
        let want = run_sweep(jobs(), 1, mock_rt).unwrap();
        let mut seen: Vec<(usize, String, u64)> = Vec::new();
        let stats = run_sweep_streaming(&js, 4, 2, mock_rt, |i, m| {
            seen.push((i, m.framework.clone(), m.iterations));
            Ok(())
        })
        .unwrap();
        assert_eq!(stats.jobs, js.len());
        assert!(
            stats.peak_buffered <= 2,
            "reorder buffer exceeded the window: {}",
            stats.peak_buffered
        );
        // In order, complete, and bit-identical to the sequential path.
        for (k, (i, fw, iters)) in seen.iter().enumerate() {
            assert_eq!(*i, k, "rows out of order");
            assert_eq!(fw, &want[k].framework);
            assert_eq!(*iters, want[k].iterations);
        }
    }

    #[test]
    fn streaming_sink_error_stops_the_sweep() {
        let js = jobs();
        let mut rows = 0usize;
        let err = run_sweep_streaming(&js, 3, 4, mock_rt, |i, _m| {
            if i == 1 {
                anyhow::bail!("sink full");
            }
            rows += 1;
            Ok(())
        })
        .unwrap_err();
        assert!(err.to_string().contains("sink full"), "{err}");
        assert_eq!(rows, 1, "only the pre-error row was consumed");
    }

    #[test]
    fn empty_sweep_is_fine_and_errors_propagate() {
        assert!(run_sweep(Vec::new(), 4, mock_rt).unwrap().is_empty());
        // Framework names are typed now (bad ones can't be built), so
        // the in-sweep failure mode left is config validation.
        let mut bad = jobs();
        bad[2].cfg.dss0 = 0;
        let err = run_sweep(bad, 4, mock_rt).unwrap_err();
        assert!(err.to_string().contains("dss0"), "{err}");
    }

    #[test]
    fn default_threads_and_window_are_positive_and_capped() {
        assert!(default_threads(0) >= 1);
        assert!(default_threads(1) == 1);
        assert!(default_threads(64) >= 1);
        assert!(default_window(0) >= 1);
        assert_eq!(default_window(4), 8);
    }
}
