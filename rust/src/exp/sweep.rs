//! Scoped-thread parallel sweep runner: one deterministic DES instance
//! per seed×framework job, fanned out over the machine's cores
//! (std-only — `std::thread::scope`, no rayon offline).
//!
//! Determinism: every job is a pure function of its [`RunConfig`] — it
//! owns a private runtime, RNG streams, event queue and metrics — so
//! running jobs concurrently and slotting results back by job index is
//! **bit-identical** to running them sequentially (asserted by
//! `parallel_sweep_matches_sequential_bitwise` below).  Only
//! `sim_wall_time` (real wall clock) differs between schedules.
//!
//! Runtimes are constructed *inside* the worker thread via the
//! `make_rt` factory because [`ModelRuntime`] boxes are deliberately
//! not `Send` (the PJRT client wrapper is `Rc`-based); each thread owns
//! its runtime end to end.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use anyhow::Result;

use crate::config::RunConfig;
use crate::frameworks::run_framework_opts;
use crate::metrics::RunMetrics;
use crate::runtime::ModelRuntime;

/// One unit of a sweep: a labelled run configuration.
pub struct SweepJob {
    /// Reported as `RunMetrics::framework` in the result row.
    pub label: String,
    pub cfg: RunConfig,
    /// Record Fig. 1-style timeline segments (costs memory; off for
    /// table sweeps).
    pub record_timeline: bool,
}

impl SweepJob {
    pub fn new(label: impl Into<String>, cfg: RunConfig) -> SweepJob {
        SweepJob { label: label.into(), cfg, record_timeline: false }
    }
}

/// Default worker-thread count for `jobs` parallel jobs: one per
/// available core, capped at the job count.
pub fn default_threads(jobs: usize) -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, jobs.max(1))
}

/// Run every job and return results in job order.
///
/// `threads == 1` is the sequential reference path; anything larger
/// fans jobs out over scoped threads pulling from a shared work index.
/// The first job error (in job order) is returned after all threads
/// finish.
pub fn run_sweep<F>(jobs: Vec<SweepJob>, threads: usize, make_rt: F) -> Result<Vec<RunMetrics>>
where
    F: Fn(&SweepJob) -> Result<Box<dyn ModelRuntime>> + Sync,
{
    let n = jobs.len();
    if n == 0 {
        return Ok(Vec::new());
    }
    let threads = threads.clamp(1, n);
    let run_one = move |job: &SweepJob| -> Result<RunMetrics> {
        let rt = make_rt(job)?;
        let exec = || run_framework_opts(job.cfg.clone(), rt, job.record_timeline);
        // A parallel sweep already saturates the cores with job-level
        // parallelism; letting every tensor op inside a job fan out
        // over `tensor::shards` workers on top of that would
        // oversubscribe (threads × shards) and pay a scoped-spawn per
        // kernel call.  Worker threads therefore pin the shard layer to
        // inline execution — bit-identical either way (DESIGN.md §12),
        // so the sequential-vs-parallel equality below is unaffected.
        let mut run = if threads > 1 {
            crate::tensor::shards::with_shards(1, exec)?
        } else {
            exec()?
        };
        run.framework = job.label.clone();
        Ok(run)
    };

    if threads == 1 {
        return jobs.iter().map(|job| run_one(job)).collect();
    }

    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<Result<RunMetrics>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    let jobs = &jobs;
    let run_one = &run_one;
    let slots_ref = &slots;
    let next_ref = &next;
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(move || loop {
                let i = next_ref.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let res = run_one(&jobs[i]);
                *slots_ref[i].lock().unwrap() = Some(res);
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| slot.into_inner().unwrap().expect("sweep job not executed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::MockRuntime;

    fn jobs() -> Vec<SweepJob> {
        crate::frameworks::ALL
            .iter()
            .map(|fw| {
                let mut cfg = crate::exp::scaled_cfg("mock", fw);
                cfg.max_iters = 120;
                cfg.target_acc = 0.88;
                SweepJob::new(*fw, cfg)
            })
            .collect()
    }

    fn mock_rt(_job: &SweepJob) -> Result<Box<dyn ModelRuntime>> {
        Ok(Box::new(MockRuntime::new()))
    }

    #[test]
    fn parallel_sweep_matches_sequential_bitwise() {
        let seq = run_sweep(jobs(), 1, mock_rt).unwrap();
        let par = run_sweep(jobs(), 4, mock_rt).unwrap();
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.framework, b.framework);
            assert_eq!(a.iterations, b.iterations, "{}", a.framework);
            assert_eq!(
                a.virtual_time.to_bits(),
                b.virtual_time.to_bits(),
                "{}",
                a.framework
            );
            assert_eq!(
                a.final_accuracy.to_bits(),
                b.final_accuracy.to_bits(),
                "{}",
                a.framework
            );
            assert_eq!(a.final_loss.to_bits(), b.final_loss.to_bits());
            assert_eq!(a.api_calls, b.api_calls, "{}", a.framework);
            assert_eq!(a.bytes, b.bytes, "{}", a.framework);
            assert_eq!(a.global_updates, b.global_updates);
            assert_eq!(a.curve, b.curve, "{}", a.framework);
            assert_eq!(a.converged, b.converged);
        }
    }

    #[test]
    fn results_preserve_job_order_and_labels() {
        let out = run_sweep(jobs(), 3, mock_rt).unwrap();
        let labels: Vec<&str> = out.iter().map(|r| r.framework.as_str()).collect();
        assert_eq!(labels, crate::frameworks::ALL.to_vec());
    }

    #[test]
    fn empty_sweep_is_fine_and_errors_propagate() {
        assert!(run_sweep(Vec::new(), 4, mock_rt).unwrap().is_empty());
        let mut bad = jobs();
        bad[2].cfg.framework = "nope".into();
        let err = run_sweep(bad, 4, mock_rt).unwrap_err();
        assert!(err.to_string().contains("unknown framework"), "{err}");
    }

    #[test]
    fn default_threads_is_positive_and_capped() {
        assert!(default_threads(0) >= 1);
        assert!(default_threads(1) == 1);
        assert!(default_threads(64) >= 1);
    }
}
