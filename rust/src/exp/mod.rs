//! Experiment drivers: one function per table/figure in the paper's
//! evaluation (DESIGN.md §5 maps each to its modules).  Every function
//! writes CSV/JSON series into an output directory and prints a short
//! summary; `hermes exp all` regenerates the complete set.
//!
//! `runtime` selects the compute backend: `mock` (host softmax
//! regression — fast, artifact-free) or a real AOT model (`cnn`,
//! `alexnet`) through the PJRT runtime.
//!
//! Seed×framework grids (`table3`, `fig14`, the Fig. 1 timeline set)
//! fan out over all cores through [`sweep::run_sweep`] — one DES
//! instance per job, results bit-identical to the sequential order.
//! Large grids (`hermes exp scale`, the churn sweep) go through the
//! *streaming* engine ([`sweep::run_sweep_streaming`]): rows arrive at
//! an incremental CSV writer in job order while at most a
//! reorder-window of results is ever resident (DESIGN.md §13).

pub mod sweep;

use std::io::Write;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::config::{ClusterConfig, RunConfig};
use crate::faults::FaultPlan;
use crate::frameworks::{policy, run_framework, PRESETS};
use crate::live::{run_live_full, LiveChaos, LiveOpts, LivePartition};
use crate::metrics::{write_file, RunMetrics, TableFmt};
use crate::runtime::{Manifest, MockRuntime, ModelRuntime, XlaRuntime};
use crate::util::fmt_duration;
use self::sweep::SweepJob;

/// Build a runtime for `model` ("mock" or a manifest model name).
pub fn make_runtime(model: &str, artifacts: &Path) -> Result<Box<dyn ModelRuntime>> {
    if model == "mock" {
        return Ok(Box::new(MockRuntime::new()));
    }
    if !artifacts.join("manifest.json").exists() {
        bail!(
            "artifacts not built (run `make artifacts`) — needed for model '{model}'"
        );
    }
    let manifest = Manifest::load(artifacts)?;
    Ok(Box::new(XlaRuntime::from_artifacts(manifest.model(model)?, None)?))
}

/// Scaled-run defaults per backend (DESIGN.md §5 scaling note).
pub fn scaled_cfg(model: &str, framework: &str) -> RunConfig {
    let mut cfg = RunConfig::new(model, framework);
    match model {
        "mock" => {
            cfg.hp.lr = 0.5;
            cfg.max_iters = 400;
            cfg.dss0 = 128;
            cfg.target_acc = 0.9;
        }
        "cnn" => {
            cfg.max_iters = 900;
            cfg.dss0 = 512;
            cfg.steps_cap = 3;
            cfg.target_acc = 0.87;
        }
        "alexnet" => {
            cfg.max_iters = 420;
            cfg.dss0 = 512;
            cfg.steps_cap = 2;
            cfg.target_acc = 0.62;
        }
        _ => {}
    }
    // Scale the SSP staleness bound and EBSP lookahead to the scaled
    // iteration budget (paper: s=125, R=150 against thousands of
    // iterations; here ~max_iters/n iterations per worker).
    cfg.hp.ssp_staleness = 6;
    cfg.hp.ebsp_lookahead = match model {
        "mock" => 4.0,
        _ => 45.0,
    };
    cfg
}

// ------------------------------------------------------------ Fig 1/10

/// Fig. 1 + Fig. 10: train/comm/wait timelines for BSP, SSP, ASP, EBSP
/// and Hermes on the contrived 4-worker cluster (one parallel sweep).
pub fn fig1_timelines(out: &Path, model: &str, artifacts: &Path) -> Result<()> {
    let mut jobs = Vec::new();
    for fw in ["bsp", "ssp", "asp", "ebsp", "hermes"] {
        let mut cfg = scaled_cfg(model, fw);
        cfg.cluster = ClusterConfig::fig1_cluster();
        cfg.hp.ssp_staleness = 2;
        cfg.max_iters = 60;
        cfg.target_acc = 1.1; // never converge: we want the timeline
        let mut job = SweepJob::new(fw, cfg);
        job.record_timeline = true;
        jobs.push(job);
    }
    let runs = run_jobs(jobs, model, artifacts, 0)?;
    for run in &runs {
        let fw = run.framework.as_str();
        let name = if fw == "hermes" { "fig10_hermes" } else { "fig1" };
        write_file(out, &format!("{name}_{fw}.csv"), &run.segments_csv())?;
        println!(
            "[fig1/10] {fw}: {} segments, {} iters, vt {}",
            run.segments.len(),
            run.iterations,
            fmt_duration(run.virtual_time)
        );
    }
    Ok(())
}

/// Shared sweep entry: `threads == 0` means one per core (resolved by
/// the sweep engine).  The runtime factory is rebuilt per job inside
/// its worker thread (`ModelRuntime` is not `Send`).
fn run_jobs(
    jobs: Vec<SweepJob>,
    model: &str,
    artifacts: &Path,
    threads: usize,
) -> Result<Vec<RunMetrics>> {
    let model = model.to_string();
    let artifacts = artifacts.to_path_buf();
    sweep::run_sweep(jobs, threads, move |_job| make_runtime(&model, &artifacts))
}

// --------------------------------------------------------------- Fig 2

/// Fig. 2: per-family breakup of one local cycle under BSP — training,
/// dataset+model receive (comm), and barrier wait.
pub fn fig2_breakdown(out: &Path, model: &str, artifacts: &Path) -> Result<()> {
    let mut cfg = scaled_cfg(model, "bsp");
    cfg.max_iters = 96; // 8 rounds × 12 workers
    cfg.target_acc = 1.1;
    let rt = make_runtime(model, artifacts)?;
    let run = run_framework(cfg, rt)?;

    let mut csv = String::from("family,train_s,comm_s,wait_s,iterations\n");
    let mut seen = std::collections::BTreeMap::<String, (f64, f64, f64, u64)>::new();
    for w in &run.workers {
        let e = seen.entry(w.family.clone()).or_default();
        e.0 += w.train_time;
        e.1 += w.comm_time;
        e.2 += w.wait_time;
        e.3 += w.iterations;
    }
    for (fam, (tr, co, wa, it)) in &seen {
        let it = (*it).max(1) as f64;
        csv += &format!(
            "{fam},{:.4},{:.4},{:.4},{it}\n",
            tr / it,
            co / it,
            wa / it
        );
    }
    write_file(out, "fig2_breakdown.csv", &csv)?;
    println!("[fig2] per-family cycle breakdown:\n{csv}");
    Ok(())
}

// --------------------------------------------------------------- Fig 3

/// Fig. 3: ASP's global-loss oscillation.
pub fn fig3_asp_oscillation(out: &Path, model: &str, artifacts: &Path) -> Result<()> {
    let mut cfg = scaled_cfg(model, "asp");
    cfg.target_acc = 1.1;
    let rt = make_runtime(model, artifacts)?;
    let run = run_framework(cfg, rt)?;
    write_file(out, "fig3_asp_loss.csv", &run.curve_csv())?;
    // Oscillation metric: count of sign flips in the loss differences.
    let flips = run
        .curve
        .windows(3)
        .filter(|w| (w[1].1 - w[0].1) * (w[2].1 - w[1].1) < 0.0)
        .count();
    println!(
        "[fig3] ASP: {} evals, {} direction flips, final loss {:.3}",
        run.curve.len(),
        flips,
        run.final_loss
    );
    Ok(())
}

// ------------------------------------------------------------ Fig 4/5

/// Fig. 4 (a: per-node training times, b: time between updates) and
/// Fig. 5 (a: per-node wait, b: fastest node's waits) for BSP.
pub fn fig4_fig5_bsp(out: &Path, model: &str, artifacts: &Path) -> Result<()> {
    let mut cfg = scaled_cfg(model, "bsp");
    cfg.max_iters = 240;
    cfg.target_acc = 1.1;
    let rt = make_runtime(model, artifacts)?;
    let run = run_framework(cfg, rt)?;

    let mut a = String::from("worker,family,mean_train_s\n");
    let mut f5a = String::from("worker,family,total_wait_s,mean_wait_s\n");
    for (i, w) in run.workers.iter().enumerate() {
        let mean_t = w.train_time / w.iterations.max(1) as f64;
        a += &format!("{i},{},{:.4}\n", w.family, mean_t);
        f5a += &format!(
            "{i},{},{:.4},{:.4}\n",
            w.family,
            w.wait_time,
            w.wait_time / w.iterations.max(1) as f64
        );
    }
    write_file(out, "fig4a_train_times.csv", &a)?;
    write_file(out, "fig5a_wait_times.csv", &f5a)?;

    let mut b = String::from("worker,gap_s\n");
    for (i, w) in run.workers.iter().enumerate() {
        for g in w.update_gaps() {
            b += &format!("{i},{g:.4}\n");
        }
    }
    write_file(out, "fig4b_update_gaps.csv", &b)?;

    // Fastest node = minimal mean train time.
    let fastest = run
        .workers
        .iter()
        .enumerate()
        .min_by(|(_, x), (_, y)| {
            (x.train_time / x.iterations.max(1) as f64)
                .partial_cmp(&(y.train_time / y.iterations.max(1) as f64))
                .unwrap()
        })
        .map(|(i, _)| i)
        .unwrap_or(0);
    write_file(
        out,
        "fig5b_fastest_node.csv",
        &format!(
            "worker,family,total_wait_s\n{fastest},{},{:.4}\n",
            run.workers[fastest].family, run.workers[fastest].wait_time
        ),
    )?;
    println!(
        "[fig4/5] BSP: fastest node {} ({}) waited {:.1}s total",
        fastest, run.workers[fastest].family, run.workers[fastest].wait_time
    );
    Ok(())
}

// -------------------------------------------------------------- Fig 11

/// Fig. 11: (a) Hermes global loss/accuracy; (b) per-family training-
/// time stabilization under dynamic allocation.
pub fn fig11_hermes(out: &Path, model: &str, artifacts: &Path) -> Result<()> {
    let mut cfg = scaled_cfg(model, "hermes");
    cfg.hp.alpha = -1.3;
    cfg.hp.beta = 0.1;
    let rt = make_runtime(model, artifacts)?;
    let run = run_framework(cfg, rt)?;
    write_file(out, "fig11a_hermes_curve.csv", &run.curve_csv())?;

    let mut b = String::from("worker,family,virtual_time,train_s\n");
    for (i, w) in run.workers.iter().enumerate() {
        for (t, dur) in &w.train_times {
            b += &format!("{i},{},{t:.3},{dur:.4}\n", w.family);
        }
    }
    write_file(out, "fig11b_train_times.csv", &b)?;
    println!(
        "[fig11] hermes: acc {:.3} in vt {}, {} pushes / {} iters",
        run.final_accuracy,
        fmt_duration(run.virtual_time),
        run.total_pushes(),
        run.iterations
    );
    Ok(())
}

// -------------------------------------------------------------- Fig 12

/// Fig. 12: dataset size sent to the weakest worker vs its training
/// time (full run + the iteration 5–10 zoom).
pub fn fig12_dynamic_sizing(out: &Path, model: &str, artifacts: &Path) -> Result<()> {
    let mut cfg = scaled_cfg(model, "hermes");
    cfg.dss0 = if model == "mock" { 512 } else { 2048 };
    cfg.mbs0 = 16;
    cfg.target_acc = 1.1;
    let (dss0, mbs0) = (cfg.dss0, cfg.mbs0);
    let rt = make_runtime(model, artifacts)?;
    let run = run_framework(cfg, rt)?;

    // Weakest worker = first B1ms node (id 0 in the paper testbed).
    let w = &run.workers[0];
    let mut csv = String::from("iteration,virtual_time,train_s,dss,mbs\n");
    let mut alloc_iter = w.allocations.iter().peekable();
    let (mut dss, mut mbs) = (dss0, mbs0);
    for (i, (t, dur)) in w.train_times.iter().enumerate() {
        while let Some(&&(at, d, m)) = alloc_iter.peek() {
            if at <= *t {
                dss = d;
                mbs = m;
                alloc_iter.next();
            } else {
                break;
            }
        }
        csv += &format!("{i},{t:.3},{dur:.4},{dss},{mbs}\n");
    }
    write_file(out, "fig12a_weakest_worker.csv", &csv)?;
    let zoom: String = csv
        .lines()
        .take(1)
        .chain(csv.lines().skip(6).take(6))
        .collect::<Vec<_>>()
        .join("\n");
    write_file(out, "fig12b_iters_5_10.csv", &zoom)?;
    println!(
        "[fig12] weakest worker: {} reallocations over {} iterations",
        w.allocations.len(),
        w.train_times.len()
    );
    Ok(())
}

// -------------------------------------------------------------- Fig 13

/// Fig. 13: global accuracy trajectory with a marker at every major
/// (gated) update from an E2ds-class worker.
pub fn fig13_major_updates(out: &Path, model: &str, artifacts: &Path) -> Result<()> {
    let cfg = scaled_cfg(model, "hermes");
    let rt = make_runtime(model, artifacts)?;
    let run = run_framework(cfg, rt)?;
    write_file(out, "fig13_global_curve.csv", &run.curve_csv())?;

    // Push markers for one E2ds_v4 worker (or worker 0 as fallback).
    let wid = run
        .workers
        .iter()
        .position(|w| w.family == "E2ds_v4")
        .unwrap_or(0);
    let mut m = String::from("push_time\n");
    for t in &run.workers[wid].push_times {
        m += &format!("{t:.3}\n");
    }
    write_file(out, "fig13_push_markers.csv", &m)?;
    println!(
        "[fig13] worker {wid} ({}): {} major updates",
        run.workers[wid].family,
        run.workers[wid].push_times.len()
    );
    Ok(())
}

// -------------------------------------------------------------- Fig 14

/// Fig. 14: α/β sensitivity — push frequency and final accuracy for
/// the paper's three (α, β) settings, swept in parallel.
pub fn fig14_alpha_beta(out: &Path, model: &str, artifacts: &Path) -> Result<()> {
    let settings = [(-0.9, 0.1), (-1.3, 0.1), (-1.6, 0.15)];
    let mut jobs = Vec::new();
    for (alpha, beta) in settings {
        let mut cfg = scaled_cfg(model, "hermes");
        cfg.hp.alpha = alpha;
        cfg.hp.beta = beta;
        jobs.push(SweepJob::new(format!("hermes(α={alpha},β={beta})"), cfg));
    }
    let runs = run_jobs(jobs, model, artifacts, 0)?;
    let mut csv = String::from("alpha,beta,pushes,iterations,final_acc,api_calls\n");
    for ((alpha, beta), run) in settings.iter().zip(&runs) {
        csv += &format!(
            "{alpha},{beta},{},{},{:.4},{}\n",
            run.total_pushes(),
            run.iterations,
            run.final_accuracy,
            run.api_calls
        );
        println!(
            "[fig14] α={alpha} β={beta}: {} pushes, acc {:.3}",
            run.total_pushes(),
            run.final_accuracy
        );
    }
    write_file(out, "fig14_alpha_beta.csv", &csv)?;
    Ok(())
}

// ------------------------------------------------------------- Table 3

/// Upper bound on the table3 job count (4 baselines + ≤3 Hermes
/// settings) — benches size their sweep width from this instead of
/// hardcoding the current job list's length.
pub const TABLE3_MAX_JOBS: usize = 7;

/// Table III: every framework on one model, with iterations, virtual
/// time, WI, accuracy, API calls and speedup vs BSP.  Rows run as one
/// parallel sweep (one core per framework).
pub fn table3(out: &Path, model: &str, artifacts: &Path) -> Result<Vec<RunMetrics>> {
    table3_with_threads(out, model, artifacts, 0)
}

/// [`table3`] with an explicit sweep width: `0` = one thread per core,
/// `1` = the sequential reference path (bit-identical results either
/// way; see `exp::sweep`).
pub fn table3_with_threads(
    out: &Path,
    model: &str,
    artifacts: &Path,
    threads: usize,
) -> Result<Vec<RunMetrics>> {
    let mut jobs: Vec<SweepJob> = Vec::new();
    for fw in ["bsp", "asp", "ssp", "ebsp"] {
        jobs.push(SweepJob::new(fw, scaled_cfg(model, fw)));
    }
    // The paper's three Hermes settings on the IID model, one on the
    // non-IID model.
    let hermes_settings: &[(f64, f64)] = if model == "alexnet" {
        &[(-1.6, 0.15)]
    } else {
        &[(-0.9, 0.1), (-1.3, 0.1), (-1.6, 0.15)]
    };
    for &(alpha, beta) in hermes_settings {
        let mut cfg = scaled_cfg(model, "hermes");
        cfg.hp.alpha = alpha;
        cfg.hp.beta = beta;
        jobs.push(SweepJob::new(format!("hermes(α={alpha},β={beta})"), cfg));
    }

    let rows = run_jobs(jobs, model, artifacts, threads)?;

    let baseline = rows[0].clone(); // BSP
    let mut table = TableFmt::new(&[
        "Framework",
        "Iterations",
        "Time",
        "WI_avg",
        "Conv. Acc.",
        "API Calls",
        "Speedup",
    ]);
    let mut json_rows = Vec::new();
    for run in &rows {
        let failed = run.crashed_workers.len() * 4 >= run.workers.len();
        if failed {
            table.row(vec![
                run.framework.clone(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
            ]);
        } else {
            table.row(vec![
                run.framework.clone(),
                run.iterations.to_string(),
                fmt_duration(run.virtual_time),
                format!("{:.2}", run.wi_avg()),
                format!("{:.2}%", run.final_accuracy * 100.0),
                run.api_calls.to_string(),
                format!("{:.2}x", run.speedup_vs(&baseline)),
            ]);
        }
        json_rows.push(run.summary_json());
    }
    let rendered = table.render();
    println!("\nTable III ({model}):\n{rendered}");
    write_file(out, &format!("table3_{model}.txt"), &rendered)?;
    write_file(
        out,
        &format!("table3_{model}.json"),
        &crate::util::json::Json::Arr(json_rows).to_string(),
    )?;
    Ok(rows)
}

// ------------------------------------------------------------- faults

/// Default churn rates swept by `hermes exp faults` (crash/rejoin
/// cycles per 100 virtual seconds, cluster-wide).
pub const FAULT_SWEEP_RATES: [f64; 3] = [0.0, 1.0, 2.5];

/// `hermes exp faults` — the churn sweep (ISSUE 2): every framework ×
/// churn rate on the same seed, reporting convergence, wall time and
/// traffic under deterministic crash/rejoin cycles.  Rows stream
/// through the sink in job order — the CSV and the terminal table are
/// built incrementally as results land.  Writes
/// `faults_churn_{model}.csv`; returns rows in (rate-major, framework-
/// minor) order.
pub fn faults_churn_sweep(
    out: &Path,
    model: &str,
    artifacts: &Path,
    threads: usize,
    rates: &[f64],
    frameworks: &[&str],
) -> Result<Vec<RunMetrics>> {
    let mut jobs = Vec::new();
    for &rate in rates {
        for fw in frameworks {
            let mut cfg = scaled_cfg(model, fw);
            cfg.faults.churn_rate = rate;
            jobs.push(SweepJob::new(format!("{fw}@churn{rate}"), cfg));
        }
    }
    let model_s = model.to_string();
    let arts = artifacts.to_path_buf();

    let mut csv = String::from(
        "framework,churn_rate,crashes,rejoins,iterations,virtual_time_s,\
         final_loss,final_accuracy,bytes,api_calls,converged\n",
    );
    let mut table = TableFmt::new(&[
        "Framework",
        "Churn",
        "Crashes",
        "Time",
        "Conv. Acc.",
        "Bytes",
    ]);
    let mut rows: Vec<RunMetrics> = Vec::with_capacity(jobs.len());
    sweep::run_sweep_streaming(
        &jobs,
        threads,
        0, // auto window
        move |_job| make_runtime(&model_s, &arts),
        |i, r| {
            // Labels come from the job itself, not re-derived index
            // arithmetic — the grid layout can change without
            // mislabeling a row.
            let cfg = &jobs[i].cfg;
            let rate = cfg.faults.churn_rate;
            let fw = cfg.framework.to_string();
            csv += &format!(
                "{fw},{rate},{},{},{},{:.3},{:.5},{:.5},{},{},{}\n",
                r.fault_crashes,
                r.fault_rejoins,
                r.iterations,
                r.virtual_time,
                r.final_loss,
                r.final_accuracy,
                r.bytes,
                r.api_calls,
                r.converged
            );
            table.row(vec![
                fw.to_string(),
                format!("{rate}"),
                format!("{}", r.fault_crashes),
                fmt_duration(r.virtual_time),
                format!("{:.2}%", r.final_accuracy * 100.0),
                r.bytes.to_string(),
            ]);
            rows.push(r);
            Ok(())
        },
    )?;
    let rendered = table.render();
    println!("\nChurn sweep ({model}):\n{rendered}");
    write_file(out, &format!("faults_churn_{model}.csv"), &csv)?;
    Ok(rows)
}

// ------------------------------------------------------------- stream

/// Rate-spread axis of `hermes exp stream`: the fastest worker's
/// arrival rate divided by the slowest's (1.0 = uniform streams, 6.0 =
/// strongly skewed edge deployment).
pub const STREAM_SWEEP_SPREADS: [f64; 2] = [1.0, 6.0];

/// Dirichlet label-skew axis (α → 0 approaches single-class shards,
/// larger α approaches IID).
pub const STREAM_SWEEP_ALPHAS: [f64; 2] = [0.3, 1.0];

/// Framework axis: static-allocation baselines against their
/// stream-aware `streamalloc` counterparts, all on the trickle curve
/// where the under-filled-buffer degradation is sharpest.
pub const STREAM_SWEEP_FRAMEWORKS: [&str; 4] = [
    "bsp@trickle",
    "bsp+streamalloc@trickle",
    "hermes@trickle",
    "hermes+streamalloc@trickle",
];

/// `hermes exp stream` — the streaming-data sweep (DESIGN.md §16):
/// framework × rate-spread × Dirichlet-α, every run fed by the seeded
/// `StreamPlan` compiler instead of a static pool.  Rows stream
/// through the sink in job order into `stream_{model}.csv`; the
/// headline contrast is a static-alloc framework starving on a trickle
/// while `streamalloc` shrinks the working set to the observed arrival
/// rate and recovers iteration throughput.
pub fn stream_sweep(
    out: &Path,
    model: &str,
    artifacts: &Path,
    threads: usize,
    spreads: &[f64],
    alphas: &[f64],
    frameworks: &[&str],
) -> Result<Vec<RunMetrics>> {
    let mut jobs = Vec::new();
    for &spread in spreads {
        for &alpha in alphas {
            for fw in frameworks {
                let mut cfg = scaled_cfg(model, fw);
                cfg.stream.spread = spread;
                cfg.stream.alpha = alpha;
                cfg.target_acc = 1.1; // fixed budget: compare throughput
                cfg.max_iters = 240;
                jobs.push(SweepJob::new(format!("{fw}|s{spread}|a{alpha}"), cfg));
            }
        }
    }
    let model_s = model.to_string();
    let arts = artifacts.to_path_buf();

    let mut csv = String::from(
        "framework,spread,alpha,iterations,virtual_time_s,iters_per_vs,\
         final_loss,final_accuracy,arrivals,skips,evictions,bytes,converged\n",
    );
    let mut table = TableFmt::new(&[
        "Framework",
        "Spread",
        "Alpha",
        "Iters",
        "Iters/s",
        "Arrivals",
        "Skips",
        "Evicted",
    ]);
    let mut rows: Vec<RunMetrics> = Vec::with_capacity(jobs.len());
    sweep::run_sweep_streaming(
        &jobs,
        threads,
        0, // auto window
        move |_job| make_runtime(&model_s, &arts),
        |i, r| {
            let cfg = &jobs[i].cfg;
            let fw = cfg.framework.to_string();
            let (spread, alpha) = (cfg.stream.spread, cfg.stream.alpha);
            let rate = r.iterations as f64 / r.virtual_time.max(1e-9);
            csv += &format!(
                "{fw},{spread},{alpha},{},{:.3},{rate:.4},{:.5},{:.5},{},{},{},{},{}\n",
                r.iterations,
                r.virtual_time,
                r.final_loss,
                r.final_accuracy,
                r.stream_arrivals,
                r.stream_skips,
                r.stream_evictions,
                r.bytes,
                r.converged
            );
            table.row(vec![
                fw,
                format!("{spread}"),
                format!("{alpha}"),
                r.iterations.to_string(),
                format!("{rate:.2}"),
                r.stream_arrivals.to_string(),
                r.stream_skips.to_string(),
                r.stream_evictions.to_string(),
            ]);
            rows.push(r);
            Ok(())
        },
    )?;
    let rendered = table.render();
    println!("\nStream sweep ({model}):\n{rendered}");
    write_file(out, &format!("stream_{model}.csv"), &csv)?;
    Ok(rows)
}

// ------------------------------------------------------------ robust

/// Chaos sweep over the failure-domain axes (DESIGN.md §15): every
/// corrupt-update species × defenses {off, on} × quorum {1.0, 0.67} on
/// the barrier (`bsp`) and elastic (`ebsp`) shapes, streamed to
/// `robust_{model}.csv`.  A live kill+restore leg — coordinator killed
/// mid-run, restored from snapshot + journal while workers reconnect
/// with backoff — is appended as the final `kill=true` row.
pub fn robust_sweep(
    out: &Path,
    model: &str,
    artifacts: &Path,
    threads: usize,
) -> Result<Vec<RunMetrics>> {
    const SPECIES: [&str; 4] = ["none", "nan", "blowup", "stale"];
    let mut jobs = Vec::new();
    let mut species_of = Vec::new();
    for fw in ["bsp", "ebsp"] {
        for &sp in &SPECIES {
            for robust in [false, true] {
                for quorum in [1.0f64, 0.67] {
                    let mut cfg = scaled_cfg(model, fw);
                    // Two injections on distinct workers, early enough
                    // that every shape still has most of its run left
                    // to recover in.
                    cfg.faults.plan = match sp {
                        "nan" => {
                            FaultPlan::new().corrupt_nan(1, 2.0).corrupt_nan(3, 4.0)
                        }
                        "blowup" => FaultPlan::new()
                            .corrupt_blowup(1, 2.0, 50.0)
                            .corrupt_blowup(3, 4.0, 50.0),
                        "stale" => FaultPlan::new()
                            .corrupt_stale(1, 2.0)
                            .corrupt_stale(3, 4.0),
                        _ => FaultPlan::new(),
                    };
                    cfg.robust.guard = robust;
                    cfg.robust.robust_agg = robust;
                    cfg.robust.quorum = quorum;
                    let label = format!(
                        "{fw}+{sp}{}{}",
                        if robust { "+robust" } else { "" },
                        if quorum < 1.0 { "+q67" } else { "" }
                    );
                    jobs.push(SweepJob::new(label, cfg));
                    species_of.push(sp);
                }
            }
        }
    }
    let model_s = model.to_string();
    let arts = artifacts.to_path_buf();

    let mut csv = String::from(
        "framework,corrupt,robust,quorum,kill,corrupt_injected,quarantined,\
         quorum_commits,restarts,dedup_skips,recovery_time_s,iterations,\
         virtual_time_s,final_loss,final_accuracy,converged\n",
    );
    let mut table = TableFmt::new(&[
        "Config",
        "Inject",
        "Quar.",
        "Q-commits",
        "Recovery",
        "Conv. Acc.",
        "Conv",
    ]);
    let mut rows: Vec<RunMetrics> = Vec::with_capacity(jobs.len());
    sweep::run_sweep_streaming(
        &jobs,
        threads,
        0, // auto window
        move |_job| make_runtime(&model_s, &arts),
        |i, r| {
            let cfg = &jobs[i].cfg;
            csv += &format!(
                "{},{},{},{},false,{},{},{},0,0,{:.3},{},{:.3},{:.5},{:.5},{}\n",
                cfg.framework,
                species_of[i],
                cfg.robust.guard,
                cfg.robust.quorum,
                r.corrupt_injected,
                r.quarantined,
                r.quorum_commits,
                r.recovery_time.unwrap_or(-1.0),
                r.iterations,
                r.virtual_time,
                r.final_loss,
                r.final_accuracy,
                r.converged
            );
            table.row(vec![
                jobs[i].label.clone(),
                format!("{}", r.corrupt_injected),
                format!("{}", r.quarantined),
                format!("{}", r.quorum_commits),
                r.recovery_time
                    .map(|t| format!("{t:.1}s"))
                    .unwrap_or_else(|| "-".into()),
                format!("{:.2}%", r.final_accuracy * 100.0),
                format!("{}", r.converged),
            ]);
            rows.push(r);
            Ok(())
        },
    )?;

    // Live kill+restore leg: the coordinator is killed mid-run and
    // restored from its snapshot + journal on a fresh port; workers
    // reconnect with bounded backoff and retried pushes are
    // dedup-skipped (applied at most once).
    let mut lcfg = RunConfig::new("mock", "hermes");
    lcfg.hp.lr = 0.5;
    lcfg.hp.alpha = -0.9;
    lcfg.hp.window = 8;
    lcfg.seed = 42;
    let opts = LiveOpts {
        kill_coordinator_at: Some(Duration::from_millis(500)),
        stop_after_pushes: Some(10),
        ..Default::default()
    };
    let rep = run_live_full(&lcfg, 2, Duration::from_secs(8), opts)?;
    csv += &format!(
        "live-kill,none,false,1,true,0,{},0,{},{},-1.000,{},{:.3},{:.5},{:.5},{}\n",
        rep.quarantined,
        rep.coordinator_restarts,
        rep.dedup_skips,
        rep.iterations,
        rep.wall_time_s,
        rep.final_loss,
        rep.final_accuracy,
        rep.final_loss.is_finite()
    );
    println!(
        "[robust] live kill+restore: {} restarts, {} dedup skips, \
         {} reconnects, {} pushes, digest {:016x}",
        rep.coordinator_restarts,
        rep.dedup_skips,
        rep.reconnects,
        rep.pushes,
        rep.model_digest
    );

    let rendered = table.render();
    println!("\nRobustness sweep ({model}):\n{rendered}");
    write_file(out, &format!("robust_{model}.csv"), &csv)?;
    Ok(rows)
}

// ------------------------------------------------------------- chaos

/// Network-chaos sweep (DESIGN.md §17): seeded frame-level fault
/// profiles — 30% drop, a drop+dup+reorder mix, and the mix plus a
/// mid-run two-way partition — over the barrier (`bsp`), elastic
/// (`ebsp`) and gated (`hermes`) shapes, streamed to
/// `chaos_{model}.csv` with the retransmit/ack/byte-ledger counters.
/// A live kill-link leg — frame drop + dup + reorder plus a real
/// partition on worker 1's TCP session, healed through the jittered
/// reconnect path — is appended as the final `live=true` row.
pub fn chaos_sweep(
    out: &Path,
    model: &str,
    artifacts: &Path,
    threads: usize,
) -> Result<Vec<RunMetrics>> {
    const PROFILES: [(&str, f64, f64, f64, f64); 4] = [
        ("none", 0.0, 0.0, 0.0, 0.0),
        ("drop30", 0.3, 0.0, 0.0, 0.0),
        ("mix", 0.2, 0.15, 0.15, 0.0),
        ("mix+part", 0.2, 0.15, 0.15, 3.0),
    ];
    let mut jobs = Vec::new();
    let mut profile_of = Vec::new();
    for fw in ["bsp", "ebsp", "hermes"] {
        for &(name, drop, dup, reorder, part_at) in &PROFILES {
            let mut cfg = scaled_cfg(model, fw);
            cfg.chaos.drop = drop;
            cfg.chaos.dup = dup;
            cfg.chaos.reorder = reorder;
            cfg.chaos.partition_at = part_at;
            jobs.push(SweepJob::new(format!("{fw}+{name}"), cfg));
            profile_of.push(name);
        }
    }
    let model_s = model.to_string();
    let arts = artifacts.to_path_buf();

    let mut csv = String::from(
        "framework,profile,live,frames_dropped,frames_retransmitted,\
         frames_duplicated,acks_sent,chaos_bytes,iterations,virtual_time_s,\
         final_loss,final_accuracy,converged\n",
    );
    let mut table = TableFmt::new(&[
        "Config",
        "Dropped",
        "Retx",
        "Dup",
        "Acks",
        "Iters",
        "Conv. Acc.",
        "Conv",
    ]);
    let mut rows: Vec<RunMetrics> = Vec::with_capacity(jobs.len());
    sweep::run_sweep_streaming(
        &jobs,
        threads,
        0, // auto window
        move |_job| make_runtime(&model_s, &arts),
        |i, r| {
            let cfg = &jobs[i].cfg;
            csv += &format!(
                "{},{},false,{},{},{},{},{},{},{:.3},{:.5},{:.5},{}\n",
                cfg.framework,
                profile_of[i],
                r.frames_dropped,
                r.frames_retransmitted,
                r.frames_duplicated,
                r.acks_sent,
                r.chaos_bytes,
                r.iterations,
                r.virtual_time,
                r.final_loss,
                r.final_accuracy,
                r.converged
            );
            table.row(vec![
                jobs[i].label.clone(),
                r.frames_dropped.to_string(),
                r.frames_retransmitted.to_string(),
                r.frames_duplicated.to_string(),
                r.acks_sent.to_string(),
                r.iterations.to_string(),
                format!("{:.2}%", r.final_accuracy * 100.0),
                format!("{}", r.converged),
            ]);
            rows.push(r);
            Ok(())
        },
    )?;

    // Live kill-link leg: seeded frame chaos on real TCP sessions plus
    // a hard partition on worker 1; the dropped pushes feed the
    // retransmit loop, the RxDedup window kills the injected dups, and
    // the partitioned worker parks then rejoins through the jittered
    // reconnect path.
    let mut lcfg = RunConfig::new("mock", "hermes");
    lcfg.hp.lr = 0.5;
    lcfg.hp.alpha = -0.9;
    lcfg.hp.window = 8;
    lcfg.seed = 42;
    let opts = LiveOpts {
        stop_after_pushes: Some(8),
        chaos: Some(LiveChaos {
            seed: 42,
            drop: 0.2,
            dup: 0.1,
            reorder: 0.1,
            partition: Some(LivePartition {
                worker: 1,
                at: Duration::from_millis(400),
                down_for: Duration::from_millis(500),
            }),
        }),
        ..Default::default()
    };
    let rep = run_live_full(&lcfg, 2, Duration::from_secs(10), opts)?;
    csv += &format!(
        "live-chaos,mix+part,true,{},{},{},{},{},{},{:.3},{:.5},{:.5},{}\n",
        rep.frames_dropped,
        rep.frames_retransmitted,
        rep.frames_duplicated,
        rep.acks_sent,
        rep.bytes_received,
        rep.iterations,
        rep.wall_time_s,
        rep.final_loss,
        rep.final_accuracy,
        rep.final_loss.is_finite()
    );
    println!(
        "[chaos] live kill-link: {} dropped, {} retransmitted, {} dup'd, \
         {} transport dups, {} acks, {} reconnects, digest {:016x}",
        rep.frames_dropped,
        rep.frames_retransmitted,
        rep.frames_duplicated,
        rep.transport_dups,
        rep.acks_sent,
        rep.reconnects,
        rep.model_digest
    );

    let rendered = table.render();
    println!("\nNetwork-chaos sweep ({model}):\n{rendered}");
    write_file(out, &format!("chaos_{model}.csv"), &csv)?;
    Ok(rows)
}

// --------------------------------------------------------- straggler

/// Mid-run slowdown factors the straggler sweep injects on worker 0
/// (×1 is the no-fault control).
pub const STRAGGLER_SLOWDOWNS: [f64; 3] = [1.0, 10.0, 100.0];

/// Straggler-supervision sweep (DESIGN.md §18): a mid-run K spike on
/// worker 0 — ×1 (control), ×10 and ×100, held to run end — over the
/// barrier (`bsp`) and elastic (`ebsp`) shapes, each with supervision
/// off and on, streamed to `straggler_{model}.csv` with the
/// health-lifecycle counters.  Fixed iteration budgets (no convergence
/// target) make the virtual-time columns an honest bounded-time
/// comparison: unsupervised barriers inherit the spike every round,
/// supervised runs cut it via speculation and eventually eviction.
pub fn straggler_sweep(
    out: &Path,
    model: &str,
    artifacts: &Path,
    threads: usize,
) -> Result<Vec<RunMetrics>> {
    let mut jobs = Vec::new();
    let mut slow_of = Vec::new();
    let mut sup_of = Vec::new();
    for fw in ["bsp", "ebsp"] {
        for &slow in &STRAGGLER_SLOWDOWNS {
            for supervise in [false, true] {
                let mut cfg = scaled_cfg(model, fw);
                cfg.max_iters = 160;
                cfg.target_acc = 1.1; // fixed budget: compare times
                if slow > 1.0 {
                    // §III-C's progressive-slowdown spike, held to the
                    // end of the run (duration far past any budget).
                    cfg.faults.plan = FaultPlan::new().k_spike(0, 8.0, 1e9, slow);
                }
                cfg.supervisor.enabled = supervise;
                if supervise {
                    // Sweep-scale tuning: probe readmission fast enough
                    // to matter within the scaled budget.
                    cfg.supervisor.probe_after_s = 20.0;
                }
                jobs.push(SweepJob::new(
                    format!("{fw} x{slow:.0} sup={}", u8::from(supervise)),
                    cfg,
                ));
                slow_of.push(slow);
                sup_of.push(supervise);
            }
        }
    }
    let model_s = model.to_string();
    let arts = artifacts.to_path_buf();

    let mut csv = String::from(
        "framework,slowdown,supervise,iterations,virtual_time_s,final_loss,\
         final_accuracy,sup_speculations,sup_spec_wins,sup_spec_dedup,\
         sup_evictions,sup_readmissions,sup_degraded_enters,\
         sup_degraded_exits,quorum_commits\n",
    );
    let mut table = TableFmt::new(&[
        "Config",
        "VT",
        "Iters",
        "Spec",
        "Wins",
        "Evict",
        "Readmit",
        "Degraded",
    ]);
    let mut rows: Vec<RunMetrics> = Vec::with_capacity(jobs.len());
    sweep::run_sweep_streaming(
        &jobs,
        threads,
        0, // auto window
        move |_job| make_runtime(&model_s, &arts),
        |i, r| {
            let cfg = &jobs[i].cfg;
            csv += &format!(
                "{},{},{},{},{:.3},{:.5},{:.5},{},{},{},{},{},{},{},{}\n",
                cfg.framework,
                slow_of[i],
                sup_of[i],
                r.iterations,
                r.virtual_time,
                r.final_loss,
                r.final_accuracy,
                r.sup_speculations,
                r.sup_spec_wins,
                r.sup_spec_dedup,
                r.sup_evictions,
                r.sup_readmissions,
                r.sup_degraded_enters,
                r.sup_degraded_exits,
                r.quorum_commits
            );
            table.row(vec![
                jobs[i].label.clone(),
                format!("{:.1}", r.virtual_time),
                r.iterations.to_string(),
                r.sup_speculations.to_string(),
                r.sup_spec_wins.to_string(),
                r.sup_evictions.to_string(),
                r.sup_readmissions.to_string(),
                r.sup_degraded_enters.to_string(),
            ]);
            rows.push(r);
            Ok(())
        },
    )?;

    let rendered = table.render();
    println!("\nStraggler-supervision sweep ({model}):\n{rendered}");
    write_file(out, &format!("straggler_{model}.csv"), &csv)?;
    Ok(rows)
}

// -------------------------------------------------------------- topo

/// Topology axis of the `hermes exp topo` sweep.
pub const TOPO_SWEEP_TOPOLOGIES: [&str; 3] = ["flat", "tree2", "tree3"];
/// Framework axis of the `hermes exp topo` sweep.
pub const TOPO_SWEEP_FRAMEWORKS: [&str; 3] = ["bsp", "ebsp", "hermes"];

/// `hermes exp topo`: the hierarchical-aggregation sweep (DESIGN.md
/// §19) — {flat, tree2, tree3} × {bsp, ebsp, hermes} over a fixed
/// iteration budget, comparing root-uplink traffic.  Tree tiers merge
/// each round's member deltas regionally and forward ONE delta upward,
/// so synchronous presets see upstream bytes drop from O(workers) to
/// O(regions) per round; GUP pushes relay verbatim (no savings, by
/// design — the gate already thinned them at the edge).  Writes
/// `topo_<model>.csv` with the per-tier traffic ledger columns.
pub fn topo_sweep(
    out: &Path,
    model: &str,
    artifacts: &Path,
    threads: usize,
) -> Result<Vec<RunMetrics>> {
    let mut jobs = Vec::new();
    for topo in TOPO_SWEEP_TOPOLOGIES {
        for fw in TOPO_SWEEP_FRAMEWORKS {
            let mut cfg = scaled_cfg(model, &format!("{fw}/{topo}"));
            cfg.max_iters = 120;
            cfg.target_acc = 1.1; // fixed budget: compare traffic
            // 12-worker testbed tree: 6 edge groups → 3 regions → root
            // (tree2 skips the group tier and uses 3 regions directly).
            cfg.topology.regions = 3;
            cfg.topology.groups = 6;
            jobs.push(SweepJob::new(format!("{fw}/{topo}"), cfg));
        }
    }
    let model_s = model.to_string();
    let arts = artifacts.to_path_buf();

    let mut csv = String::from(
        "framework,topology,regions,iterations,virtual_time_s,final_loss,\
         final_accuracy,bytes,tier_upstream_bytes,tier_upstream_updates,\
         tier_mid_bytes,tier_mid_updates,tier_gate_admits,\
         tier_gate_suppressed\n",
    );
    let mut table = TableFmt::new(&[
        "Config",
        "VT",
        "Iters",
        "Regions",
        "Upstream B",
        "Up updates",
        "Mid B",
    ]);
    let mut rows: Vec<RunMetrics> = Vec::with_capacity(jobs.len());
    sweep::run_sweep_streaming(
        &jobs,
        threads,
        0, // auto window
        move |_job| make_runtime(&model_s, &arts),
        |i, r| {
            let cfg = &jobs[i].cfg;
            csv += &format!(
                "{},{},{},{},{:.3},{:.5},{:.5},{},{},{},{},{},{},{}\n",
                cfg.framework,
                cfg.framework.topo.token(),
                r.tier_regions,
                r.iterations,
                r.virtual_time,
                r.final_loss,
                r.final_accuracy,
                r.bytes,
                r.tier_upstream_bytes,
                r.tier_upstream_updates,
                r.tier_mid_bytes,
                r.tier_mid_updates,
                r.tier_gate_admits,
                r.tier_gate_suppressed
            );
            table.row(vec![
                jobs[i].label.clone(),
                format!("{:.1}", r.virtual_time),
                r.iterations.to_string(),
                r.tier_regions.to_string(),
                r.tier_upstream_bytes.to_string(),
                r.tier_upstream_updates.to_string(),
                r.tier_mid_bytes.to_string(),
            ]);
            rows.push(r);
            Ok(())
        },
    )?;

    let rendered = table.render();
    println!("\nTopology sweep ({model}):\n{rendered}");
    write_file(out, &format!("topo_{model}.csv"), &csv)?;
    Ok(rows)
}

// ------------------------------------------------------------- scale

/// Which framework axis a scale sweep fans over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleGrid {
    /// The six canonical presets (the pre-policy-API behaviour).
    Preset,
    /// The full 24-spec composition grid (sync × gate × alloc,
    /// DESIGN.md §14) — every hybrid becomes a sweep axis point.
    Hybrid,
}

impl ScaleGrid {
    pub fn parse(s: &str) -> Result<ScaleGrid, String> {
        match s {
            "preset" => Ok(ScaleGrid::Preset),
            "hybrid" => Ok(ScaleGrid::Hybrid),
            other => Err(format!("unknown grid '{other}' (preset | hybrid)")),
        }
    }

    /// The framework-spec axis of this grid, as spec strings.
    pub fn specs(&self) -> Vec<String> {
        match self {
            ScaleGrid::Preset => PRESETS.iter().map(|s| s.to_string()).collect(),
            ScaleGrid::Hybrid => {
                policy::grid_specs().iter().map(|s| s.to_string()).collect()
            }
        }
    }
}

/// Build an `n`-job seed×framework×churn grid for the streaming scale
/// sweep: the framework spec cycles fastest, then the churn rate, and
/// every job gets its own seed — `n` distinct scenarios,
/// deterministically.  Budgets are kept tiny per job (the point is
/// sweep throughput, not per-run convergence).
pub fn scale_jobs(model: &str, n: usize) -> Vec<SweepJob> {
    scale_jobs_grid(model, n, ScaleGrid::Preset)
}

/// [`scale_jobs`] over an explicit framework axis — `--grid hybrid`
/// fans the whole composition grid through the streaming sweep.
pub fn scale_jobs_grid(model: &str, n: usize, grid: ScaleGrid) -> Vec<SweepJob> {
    let fws = grid.specs();
    (0..n)
        .map(|i| {
            let fw = &fws[i % fws.len()];
            let mut cfg = scaled_cfg(model, fw);
            cfg.seed = 1000 + i as u64;
            cfg.max_iters = 24;
            cfg.dss0 = 64;
            cfg.target_acc = 1.1; // never converge: fixed-size jobs
            cfg.faults.churn_rate =
                FAULT_SWEEP_RATES[(i / fws.len()) % FAULT_SWEEP_RATES.len()];
            SweepJob::new(format!("{fw}#{i}"), cfg)
        })
        .collect()
}

/// What [`scale_sweep`] measured.
#[derive(Debug, Clone, Copy)]
pub struct ScaleReport {
    pub jobs: usize,
    pub seconds: f64,
    pub jobs_per_sec: f64,
    /// Peak result rows resident at once (streaming: ≤ the reorder
    /// window; collect-all: the whole grid).
    pub peak_resident_rows: usize,
}

/// `hermes exp scale` — the streaming 10k-job sweep (DESIGN.md §13):
/// run an `n_jobs` seed×framework×churn grid, writing one CSV row per
/// finished job **incrementally** (a `BufWriter` sink fed in job
/// order), so memory stays bounded by the reorder window no matter the
/// grid size.  `collect_all = true` runs the same grid through the
/// collect-then-write path instead — the before/after comparison
/// `benches/sweep_scaling.rs` records in `BENCH_sweep.json`.
pub fn scale_sweep(
    out: &Path,
    model: &str,
    artifacts: &Path,
    n_jobs: usize,
    threads: usize,
    collect_all: bool,
    grid: ScaleGrid,
) -> Result<ScaleReport> {
    let jobs = scale_jobs_grid(model, n_jobs, grid);
    let model_s = model.to_string();
    let arts = artifacts.to_path_buf();
    let make_rt = move |_job: &SweepJob| make_runtime(&model_s, &arts);

    std::fs::create_dir_all(out)?;
    let path = out.join(format!("scale_{model}.csv"));
    let mut w = std::io::BufWriter::new(std::fs::File::create(&path)?);
    writeln!(
        w,
        "job,framework,seed,churn_rate,iterations,virtual_time_s,\
         final_loss,final_accuracy,bytes,api_calls"
    )?;
    // Row labels come from the jobs themselves (the authoritative
    // grid), not from re-derived index arithmetic — reordering or
    // extending `scale_jobs` can never mislabel the CSV.
    let labels: Vec<(String, f64)> = jobs
        .iter()
        .map(|j| (j.cfg.framework.to_string(), j.cfg.faults.churn_rate))
        .collect();
    let write_row = |w: &mut dyn Write, i: usize, r: &RunMetrics| -> Result<()> {
        let (fw, churn) = &labels[i];
        writeln!(
            w,
            "{i},{fw},{},{churn},{},{:.3},{:.5},{:.5},{},{}",
            r.seed,
            r.iterations,
            r.virtual_time,
            r.final_loss,
            r.final_accuracy,
            r.bytes,
            r.api_calls
        )?;
        Ok(())
    };

    let t0 = Instant::now();
    let (jobs_done, peak) = if collect_all {
        let rows = sweep::run_sweep(jobs, threads, make_rt)?;
        let n = rows.len();
        for (i, r) in rows.iter().enumerate() {
            write_row(&mut w, i, r)?;
        }
        (n, n)
    } else {
        let stats =
            sweep::run_sweep_streaming(&jobs, threads, 0, make_rt, |i, r| {
                write_row(&mut w, i, &r)
            })?;
        (stats.jobs, stats.peak_buffered)
    };
    w.flush()?;
    let seconds = t0.elapsed().as_secs_f64();
    let report = ScaleReport {
        jobs: jobs_done,
        seconds,
        jobs_per_sec: jobs_done as f64 / seconds.max(1e-9),
        peak_resident_rows: peak,
    };
    let threads_desc = if threads == 0 {
        "auto".to_string()
    } else {
        threads.to_string()
    };
    println!(
        "[scale] {model}: {} jobs in {:.2}s — {:.1} jobs/s, {threads_desc} threads, \
         peak {} resident rows ({}), rows → {}",
        report.jobs,
        report.seconds,
        report.jobs_per_sec,
        report.peak_resident_rows,
        if collect_all { "collect-all" } else { "streaming" },
        path.display()
    );
    Ok(report)
}

/// Run the complete experiment suite.
pub fn run_all(out: &Path, model: &str, artifacts: &Path) -> Result<()> {
    fig1_timelines(out, model, artifacts)?;
    fig2_breakdown(out, model, artifacts)?;
    fig3_asp_oscillation(out, model, artifacts)?;
    fig4_fig5_bsp(out, model, artifacts)?;
    fig11_hermes(out, model, artifacts)?;
    fig12_dynamic_sizing(out, model, artifacts)?;
    fig13_major_updates(out, model, artifacts)?;
    fig14_alpha_beta(out, model, artifacts)?;
    table3(out, model, artifacts)?;
    faults_churn_sweep(out, model, artifacts, 0, &FAULT_SWEEP_RATES, &PRESETS)?;
    straggler_sweep(out, model, artifacts, 0)?;
    topo_sweep(out, model, artifacts, 0)?;
    stream_sweep(
        out,
        model,
        artifacts,
        0,
        &STREAM_SWEEP_SPREADS,
        &STREAM_SWEEP_ALPHAS,
        &STREAM_SWEEP_FRAMEWORKS,
    )?;
    println!("\nAll experiment outputs in {}", out.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scaled_cfgs_are_valid() {
        for model in ["mock", "cnn", "alexnet"] {
            for fw in PRESETS {
                scaled_cfg(model, fw).validate().unwrap();
            }
            // Hybrid specs get the same scaled budgets.
            for spec in policy::hybrid_specs() {
                scaled_cfg(model, &spec.to_string()).validate().unwrap();
            }
        }
    }

    #[test]
    fn make_runtime_mock_never_needs_artifacts() {
        let rt = make_runtime("mock", Path::new("/nonexistent")).unwrap();
        assert_eq!(rt.meta().name, "mock");
        assert!(make_runtime("cnn", Path::new("/nonexistent")).is_err());
    }

    #[test]
    fn faults_sweep_writes_csv_and_counts_churn() {
        let dir = std::env::temp_dir().join("hermes_exp_faults_test");
        let rows = faults_churn_sweep(
            &dir,
            "mock",
            Path::new("/nonexistent"),
            0,
            &[0.0, 3.0],
            &["hermes"],
        )
        .unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].fault_crashes, 0, "rate 0 must inject nothing");
        assert!(dir.join("faults_churn_mock.csv").exists());
        let csv = std::fs::read_to_string(dir.join("faults_churn_mock.csv")).unwrap();
        assert_eq!(csv.lines().count(), 3, "{csv}");
        assert!(csv.lines().nth(1).unwrap().starts_with("hermes,0,"), "{csv}");
    }

    #[test]
    fn topo_sweep_trees_cut_upstream_bytes_for_sync_presets() {
        let dir = std::env::temp_dir().join("hermes_exp_topo_test");
        let rows = topo_sweep(&dir, "mock", Path::new("/nonexistent"), 0).unwrap();
        // {flat, tree2, tree3} × {bsp, ebsp, hermes}, topology outermost.
        assert_eq!(rows.len(), 9);
        let csv = std::fs::read_to_string(dir.join("topo_mock.csv")).unwrap();
        assert_eq!(csv.lines().count(), 10, "{csv}");
        assert!(csv.lines().nth(1).unwrap().starts_with("bsp,flat,0,"), "{csv}");
        let at = |t: usize, f: usize| &rows[t * 3 + f];
        for f in 0..TOPO_SWEEP_FRAMEWORKS.len() {
            // Same fixed budget everywhere: traffic is comparable.
            assert_eq!(at(0, f).iterations, at(1, f).iterations);
            assert_eq!(at(0, f).iterations, at(2, f).iterations);
        }
        for (f, fw) in ["bsp", "ebsp"].into_iter().enumerate() {
            for t in [1, 2] {
                assert!(
                    at(t, f).tier_upstream_bytes < at(0, f).tier_upstream_bytes,
                    "{fw}/{}: upstream {} !< flat {}",
                    TOPO_SWEEP_TOPOLOGIES[t],
                    at(t, f).tier_upstream_bytes,
                    at(0, f).tier_upstream_bytes
                );
            }
        }
        // GUP pushes relay verbatim: the gate already thinned them at
        // the edge, so the tree adds accounting but no extra savings.
        assert_eq!(at(1, 2).tier_upstream_bytes, at(0, 2).tier_upstream_bytes);
        // Tree runs carry a live regional ledger; flat synthesizes one.
        assert_eq!(at(0, 0).tier_regions, 0);
        assert_eq!(at(1, 0).tier_regions, 3);
        assert_eq!(at(2, 0).tier_regions, 3);
        assert!(at(2, 0).tier_mid_bytes > 0, "tree3 must charge the mid tier");
    }

    #[test]
    fn straggler_sweep_writes_csv_with_lifecycle_counters() {
        let dir = std::env::temp_dir().join("hermes_exp_straggler_test");
        let rows = straggler_sweep(&dir, "mock", Path::new("/nonexistent"), 0).unwrap();
        // {bsp, ebsp} × {×1, ×10, ×100} × {off, on}.
        assert_eq!(rows.len(), 12);
        let csv = std::fs::read_to_string(dir.join("straggler_mock.csv")).unwrap();
        assert_eq!(csv.lines().count(), 13, "{csv}");
        assert!(csv.lines().nth(1).unwrap().starts_with("bsp,1,false,"), "{csv}");
        for r in &rows {
            assert!(r.iterations > 0, "{}: no iterations", r.framework);
            assert!(r.final_loss.is_finite(), "{}: loss", r.framework);
        }
        // Unsupervised rows never touch the supervisor counters.
        for (i, r) in rows.iter().enumerate() {
            if i % 2 == 0 {
                assert_eq!(r.sup_speculations, 0, "row {i}");
                assert_eq!(r.sup_evictions, 0, "row {i}");
            }
        }
        // Headline contrast (ISSUE 9 acceptance): under the ×100 spike
        // the supervised barrier run is bounded well below the
        // unsupervised one, which inherits the spike every round.
        let unsup = &rows[4]; // bsp ×100 sup=off
        let sup = &rows[5]; // bsp ×100 sup=on
        assert!(
            sup.virtual_time < unsup.virtual_time,
            "supervised {} vs unsupervised {}",
            sup.virtual_time,
            unsup.virtual_time
        );
        assert!(
            sup.sup_speculations > 0 || sup.sup_evictions > 0,
            "supervision never intervened"
        );
    }

    #[test]
    fn stream_sweep_writes_csv_and_streamalloc_recovers_throughput() {
        let dir = std::env::temp_dir().join("hermes_exp_stream_test");
        let rows = stream_sweep(
            &dir,
            "mock",
            Path::new("/nonexistent"),
            0,
            &[1.0],
            &[0.3],
            &["bsp@trickle", "bsp+streamalloc@trickle"],
        )
        .unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.stream_arrivals > 0, "{}: no arrivals", r.framework);
            assert!(r.iterations > 0, "{}: no iterations", r.framework);
        }
        // The headline contrast (ISSUE 7 acceptance): the stream-aware
        // allocator out-iterates the static allocation on the same
        // trickle, because it shrinks DSS to the observed arrival rate
        // instead of waiting for a full static working set each round.
        assert!(
            rows[1].iterations > rows[0].iterations,
            "streamalloc {} iters vs static {}",
            rows[1].iterations,
            rows[0].iterations
        );
        let csv = std::fs::read_to_string(dir.join("stream_mock.csv")).unwrap();
        assert_eq!(csv.lines().count(), 3, "{csv}");
        assert!(
            csv.lines().nth(1).unwrap().starts_with("bsp@trickle,1,0.3,"),
            "{csv}"
        );
    }

    #[test]
    fn scale_sweep_streaming_and_collect_write_identical_rows() {
        let dir = std::env::temp_dir().join("hermes_exp_scale_test");
        let rep = scale_sweep(
            &dir,
            "mock",
            Path::new("/nonexistent"),
            8,
            2,
            false,
            ScaleGrid::Preset,
        )
        .unwrap();
        assert_eq!(rep.jobs, 8);
        assert!(rep.jobs_per_sec > 0.0);
        assert!(
            rep.peak_resident_rows <= sweep::default_window(2),
            "streaming must bound residency: {}",
            rep.peak_resident_rows
        );
        let streamed =
            std::fs::read_to_string(dir.join("scale_mock.csv")).unwrap();
        assert_eq!(streamed.lines().count(), 9, "{streamed}");
        assert!(streamed.lines().nth(1).unwrap().starts_with("0,bsp,1000,"));

        // The collect-all baseline writes byte-identical rows (jobs are
        // pure functions of their configs).
        let rep2 = scale_sweep(
            &dir,
            "mock",
            Path::new("/nonexistent"),
            8,
            2,
            true,
            ScaleGrid::Preset,
        )
        .unwrap();
        assert_eq!(rep2.peak_resident_rows, 8, "collect-all holds the grid");
        let collected =
            std::fs::read_to_string(dir.join("scale_mock.csv")).unwrap();
        assert_eq!(streamed, collected);
    }

    #[test]
    fn scale_jobs_cycle_frameworks_seeds_and_churn() {
        let jobs = scale_jobs("mock", 14);
        assert_eq!(jobs.len(), 14);
        let fws = PRESETS;
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.cfg.framework.to_string(), fws[i % fws.len()]);
            assert_eq!(j.cfg.seed, 1000 + i as u64);
            j.cfg.validate().unwrap();
        }
        // Second framework cycle advances the churn rate.
        assert_eq!(jobs[0].cfg.faults.churn_rate, FAULT_SWEEP_RATES[0]);
        assert_eq!(jobs[fws.len()].cfg.faults.churn_rate, FAULT_SWEEP_RATES[1]);
    }

    #[test]
    fn hybrid_grid_cycles_all_24_specs_through_the_streaming_sweep() {
        let specs = ScaleGrid::Hybrid.specs();
        assert_eq!(specs.len(), 24);
        let jobs = scale_jobs_grid("mock", 26, ScaleGrid::Hybrid);
        assert_eq!(jobs.len(), 26);
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.cfg.framework.to_string(), specs[i % specs.len()]);
            j.cfg.validate().unwrap();
        }
        // The named hybrid scenarios are reachable grid points.
        for named in ["bsp+dynalloc", "ssp+gup", "selsync+dynalloc"] {
            assert!(specs.iter().any(|s| s == named), "{named} not in the grid");
        }
        // Job 25 wraps: same spec axis as job 1, a different seed.
        assert_eq!(
            jobs[24].cfg.framework.to_string(),
            jobs[0].cfg.framework.to_string()
        );
        assert_ne!(jobs[24].cfg.seed, jobs[0].cfg.seed);
    }

    #[test]
    fn scale_sweep_hybrid_grid_streams_end_to_end() {
        let dir = std::env::temp_dir().join("hermes_exp_scale_hybrid_test");
        let rep = scale_sweep(
            &dir,
            "mock",
            Path::new("/nonexistent"),
            24,
            2,
            false,
            ScaleGrid::Hybrid,
        )
        .unwrap();
        assert_eq!(rep.jobs, 24);
        let csv = std::fs::read_to_string(dir.join("scale_mock.csv")).unwrap();
        assert_eq!(csv.lines().count(), 25, "{csv}");
        for named in ["bsp+dynalloc", "ssp+gup", "selsync+dynalloc"] {
            assert!(
                csv.lines().any(|l| l.contains(&format!(",{named},"))),
                "{named} row missing:\n{csv}"
            );
        }
    }

    #[test]
    fn table3_mock_produces_all_rows() {
        let dir = std::env::temp_dir().join("hermes_exp_test");
        let rows = table3(&dir, "mock", Path::new("/nonexistent")).unwrap();
        assert_eq!(rows.len(), 7); // bsp asp ssp ebsp + 3 hermes
        // Hermes rows must beat BSP on virtual time (the headline).
        let bsp_t = rows[0].virtual_time;
        let best_hermes = rows[4..]
            .iter()
            .map(|r| r.virtual_time)
            .fold(f64::MAX, f64::min);
        assert!(
            best_hermes < bsp_t,
            "hermes {best_hermes:.1}s not faster than BSP {bsp_t:.1}s"
        );
        assert!(dir.join("table3_mock.txt").exists());
        assert!(dir.join("table3_mock.json").exists());
    }
}
