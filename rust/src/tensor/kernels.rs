//! Runtime-dispatched compute kernels for the aggregation algebra
//! (DESIGN.md §12) and the worker training fast path (DESIGN.md §13).
//! Every elementwise hot op — `axpy`, `scale`, `weighted_sum`,
//! `delta_over_eta`, `copy`, `fill`, the f16/f32 wire-codec inner
//! loops, and the worker-compute trio `gemm_bias` / `rank1_acc` /
//! `sgd_momentum` — exists twice: a portable scalar loop and an x86_64
//! AVX2 (+F16C for the f16 encode) implementation selected once at
//! runtime via `is_x86_feature_detected!`.  No new dependencies: only
//! `std::arch`.
//!
//! **Bit-identity contract.**  The SIMD paths perform the *same*
//! per-element operations in the same order as the scalar loops —
//! explicit mul-then-add (never FMA, which would fuse the rounding
//! step), IEEE division (never a reciprocal approximation), and a
//! scalar tail for the `len % 8` remainder lanes.  Elementwise ops
//! reassociate nothing, so scalar and SIMD results are bit-identical
//! for all non-NaN inputs (NaN *payload* propagation through `mul` is
//! the one case IEEE leaves to the hardware; parameter/gradient tensors
//! carry no NaNs).  Property tests in this file and in
//! `tests/coordinator_props.rs` enforce the contract over random
//! shapes, remainder lanes and the full f16 bit space.
//!
//! Reductions (`l2_norm`, `relative_change`) are deliberately *not*
//! here: vectorizing a sum reassociates the additions and changes the
//! bits (see DESIGN.md §12 and `ParamVec::l2_norm`).
//!
//! Dispatch order: `with_backend` override (tests/benches) →
//! `HERMES_FORCE_SCALAR` env var → CPU detection.  All three resolve to
//! the same results; only the instructions differ.

use std::cell::Cell;
use std::sync::OnceLock;

/// Which implementation family executes the kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Portable scalar loops — always available, the reference
    /// semantics.
    Scalar,
    /// x86_64 AVX2 lanes (+F16C for the f16 encode when the CPU has
    /// it).  Requesting `Simd` on a CPU without AVX2 silently runs
    /// `Scalar` — the results are bit-identical either way.
    Simd,
}

#[derive(Debug, Clone, Copy)]
struct Caps {
    avx2: bool,
    f16c: bool,
}

fn caps() -> Caps {
    static C: OnceLock<Caps> = OnceLock::new();
    *C.get_or_init(|| {
        #[allow(unused_mut)]
        let mut c = Caps { avx2: false, f16c: false };
        #[cfg(target_arch = "x86_64")]
        {
            c.avx2 = std::arch::is_x86_feature_detected!("avx2");
            c.f16c = c.avx2 && std::arch::is_x86_feature_detected!("f16c");
        }
        c
    })
}

/// Does this CPU have the AVX2 kernel path at all?
pub fn simd_available() -> bool {
    caps().avx2
}

/// Does this CPU have the hardware f16 encode (F16C) path?
pub fn f16c_available() -> bool {
    caps().f16c
}

const MODE_AUTO: u8 = 0;
const MODE_SCALAR: u8 = 1;
const MODE_SIMD: u8 = 2;

thread_local! {
    /// Per-thread test/bench override; `MODE_AUTO` defers to env +
    /// detection.  Thread-local so concurrently running tests can force
    /// different backends without racing each other; the shard runners
    /// re-apply the caller's resolved backend on their scoped workers.
    static OVERRIDE: Cell<u8> = const { Cell::new(MODE_AUTO) };
}

fn env_default() -> Backend {
    static D: OnceLock<Backend> = OnceLock::new();
    *D.get_or_init(|| {
        let forced = std::env::var("HERMES_FORCE_SCALAR")
            .map(|v| v != "0")
            .unwrap_or(false);
        if !forced && caps().avx2 {
            Backend::Simd
        } else {
            Backend::Scalar
        }
    })
}

/// The backend the next kernel call on this thread dispatches to.
pub fn active_backend() -> Backend {
    match OVERRIDE.with(|c| c.get()) {
        MODE_SCALAR => Backend::Scalar,
        MODE_SIMD if caps().avx2 => Backend::Simd,
        MODE_SIMD => Backend::Scalar,
        _ => env_default(),
    }
}

/// Run `f` with this thread's kernel backend forced to `b`, restoring
/// the previous mode afterwards.  A test/bench hook; because every
/// backend is bit-identical, forcing is a perf choice, never a semantic
/// one.  The shard runners re-apply the caller's resolved backend on
/// their scoped workers, so a forced section shards onto the same
/// backend; other threads are unaffected.
pub fn with_backend<R>(b: Backend, f: impl FnOnce() -> R) -> R {
    let mode = match b {
        Backend::Scalar => MODE_SCALAR,
        Backend::Simd => MODE_SIMD,
    };
    let prev = OVERRIDE.with(|c| c.replace(mode));
    let out = f();
    OVERRIDE.with(|c| c.set(prev));
    out
}

// ------------------------------------------------------- dispatchers

/// dst\[i\] = v
pub fn fill(dst: &mut [f32], v: f32) {
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Simd => unsafe { avx2::fill(dst, v) },
        _ => scalar::fill(dst, v),
    }
}

/// dst ← src (lengths must match).
pub fn copy(dst: &mut [f32], src: &[f32]) {
    // memcpy is optimal on every backend; dispatch would add nothing.
    scalar::copy(dst, src);
}

/// dst\[i\] *= alpha
pub fn scale_in_place(dst: &mut [f32], alpha: f32) {
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Simd => unsafe { avx2::scale_in_place(dst, alpha) },
        _ => scalar::scale_in_place(dst, alpha),
    }
}

/// dst\[i\] += alpha * y\[i\]
pub fn axpy_in_place(dst: &mut [f32], alpha: f32, y: &[f32]) {
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Simd => unsafe { avx2::axpy_in_place(dst, alpha, y) },
        _ => scalar::axpy_in_place(dst, alpha, y),
    }
}

/// dst\[i\] = x\[i\] + alpha * y\[i\]
pub fn axpy_out(dst: &mut [f32], x: &[f32], alpha: f32, y: &[f32]) {
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Simd => unsafe { avx2::axpy_out(dst, x, alpha, y) },
        _ => scalar::axpy_out(dst, x, alpha, y),
    }
}

/// dst\[i\] = wa * a\[i\] + wb * b\[i\]
pub fn weighted_sum(dst: &mut [f32], a: &[f32], wa: f32, b: &[f32], wb: f32) {
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Simd => unsafe { avx2::weighted_sum(dst, a, wa, b, wb) },
        _ => scalar::weighted_sum(dst, a, wa, b, wb),
    }
}

/// dst\[i\] = (a\[i\] - b\[i\]) / eta   (true IEEE division, both paths)
pub fn delta_over_eta(dst: &mut [f32], a: &[f32], b: &[f32], eta: f32) {
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Simd => unsafe { avx2::delta_over_eta(dst, a, b, eta) },
        _ => scalar::delta_over_eta(dst, a, b, eta),
    }
}

/// Encode `xs` as little-endian f16 into `dst` (`dst.len() == 2*xs.len()`).
/// SIMD path = hardware F16C with round-to-nearest-even — the same
/// rounding `util::f16::f32_to_f16_bits` implements in software
/// (equality over the full f16-exact space is tested below).
pub fn f16_encode(xs: &[f32], dst: &mut [u8]) {
    debug_assert_eq!(dst.len(), 2 * xs.len());
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Simd if caps().f16c => unsafe { f16c::encode(xs, dst) },
        _ => scalar::f16_encode(xs, dst),
    }
}

/// Decode little-endian f16 bytes into `dst` (`src.len() == 2*dst.len()`).
/// SIMD path = integer expand + one exact power-of-two multiply (the
/// "magic multiply": normals and subnormals scale exactly, inf/NaN are
/// blended from the carried bits) — bit-identical to the scalar decode
/// for every one of the 65536 f16 patterns, signaling NaNs included.
pub fn f16_decode(src: &[u8], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), 2 * dst.len());
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Simd => unsafe { avx2::f16_decode(src, dst) },
        _ => scalar::f16_decode(src, dst),
    }
}

// ------------------------------------------- worker-compute kernels
//
// The worker fast path (DESIGN.md §13): the softmax-regression forward,
// the rank-1 gradient accumulation and the fused SGD(M) update of
// `runtime::MockRuntime`.  SIMD lanes vectorize the *class/column*
// axis; the per-element operation sequence (accumulation order over
// features, mul-then-add, no FMA) is exactly the scalar reference's,
// so backends are bit-identical like every other kernel in this file.

/// out\[r·cols + c\] = bias\[c\] + Σ_f x\[r·feat + f\] · w\[f·cols + c\],
/// accumulated in `f` index order (the scalar reference order of the
/// softmax-regression forward).
pub fn gemm_bias(
    out: &mut [f32],
    x: &[f32],
    w: &[f32],
    bias: &[f32],
    rows: usize,
    feat: usize,
    cols: usize,
) {
    debug_assert_eq!(out.len(), rows * cols);
    debug_assert_eq!(x.len(), rows * feat);
    debug_assert_eq!(w.len(), feat * cols);
    debug_assert_eq!(bias.len(), cols);
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Simd => unsafe { avx2::gemm_bias(out, x, w, bias, rows, feat, cols) },
        _ => scalar::gemm_bias(out, x, w, bias, rows, feat, cols),
    }
}

/// gw\[f·cols + c\] += g\[c\] · x\[f\] — one sample's rank-1 gradient
/// update (`feat = x.len()`).  Each output element receives exactly one
/// mul-then-add per call, so the caller's sample order fixes the
/// accumulation order.
pub fn rank1_acc(gw: &mut [f32], x: &[f32], g: &[f32], cols: usize) {
    debug_assert_eq!(gw.len(), x.len() * cols);
    debug_assert_eq!(g.len(), cols);
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Simd => unsafe { avx2::rank1_acc(gw, x, g, cols) },
        _ => scalar::rank1_acc(gw, x, g, cols),
    }
}

/// Fused SGD-with-momentum update, elementwise and in place:
/// m\[i\] = mu·m\[i\] + g\[i\];  p\[i\] = p\[i\] − lr·m\[i\].
pub fn sgd_momentum(p: &mut [f32], m: &mut [f32], g: &[f32], lr: f32, mu: f32) {
    debug_assert_eq!(p.len(), m.len());
    debug_assert_eq!(p.len(), g.len());
    match active_backend() {
        #[cfg(target_arch = "x86_64")]
        Backend::Simd => unsafe { avx2::sgd_momentum(p, m, g, lr, mu) },
        _ => scalar::sgd_momentum(p, m, g, lr, mu),
    }
}

/// Serialize `xs` as little-endian f32 bytes (`dst.len() == 4*xs.len()`).
/// On little-endian targets this is one memcpy regardless of backend;
/// the portable loop only runs on big-endian hosts.
pub fn f32_write_le(xs: &[f32], dst: &mut [u8]) {
    debug_assert_eq!(dst.len(), 4 * xs.len());
    if cfg!(target_endian = "little") {
        // SAFETY: f32 has no padding; on LE hosts its memory bytes are
        // exactly its to_le_bytes(), and the ranges cannot overlap
        // (&mut exclusivity).
        unsafe {
            std::ptr::copy_nonoverlapping(
                xs.as_ptr() as *const u8,
                dst.as_mut_ptr(),
                dst.len(),
            );
        }
    } else {
        scalar::f32_write_le(xs, dst);
    }
}

/// Deserialize little-endian f32 bytes (`src.len() == 4*dst.len()`).
pub fn f32_read_le(src: &[u8], dst: &mut [f32]) {
    debug_assert_eq!(src.len(), 4 * dst.len());
    if cfg!(target_endian = "little") {
        // SAFETY: see `f32_write_le`; every u32 bit pattern is a valid
        // f32 (possibly NaN), so copying raw bytes is sound.
        unsafe {
            std::ptr::copy_nonoverlapping(
                src.as_ptr(),
                dst.as_mut_ptr() as *mut u8,
                src.len(),
            );
        }
    } else {
        scalar::f32_read_le(src, dst);
    }
}

// ---------------------------------------------------- scalar backend

mod scalar {
    use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};

    pub fn fill(dst: &mut [f32], v: f32) {
        for x in dst {
            *x = v;
        }
    }

    pub fn copy(dst: &mut [f32], src: &[f32]) {
        dst.copy_from_slice(src);
    }

    pub fn scale_in_place(dst: &mut [f32], alpha: f32) {
        for x in dst {
            *x *= alpha;
        }
    }

    pub fn axpy_in_place(dst: &mut [f32], alpha: f32, y: &[f32]) {
        for (x, y) in dst.iter_mut().zip(y) {
            *x += alpha * y;
        }
    }

    pub fn axpy_out(dst: &mut [f32], x: &[f32], alpha: f32, y: &[f32]) {
        for ((z, x), y) in dst.iter_mut().zip(x).zip(y) {
            *z = x + alpha * y;
        }
    }

    pub fn weighted_sum(dst: &mut [f32], a: &[f32], wa: f32, b: &[f32], wb: f32) {
        for ((z, x), y) in dst.iter_mut().zip(a).zip(b) {
            *z = wa * x + wb * y;
        }
    }

    pub fn delta_over_eta(dst: &mut [f32], a: &[f32], b: &[f32], eta: f32) {
        for ((z, x), y) in dst.iter_mut().zip(a).zip(b) {
            *z = (x - y) / eta;
        }
    }

    pub fn gemm_bias(
        out: &mut [f32],
        x: &[f32],
        w: &[f32],
        bias: &[f32],
        rows: usize,
        feat: usize,
        cols: usize,
    ) {
        for r in 0..rows {
            let xi = &x[r * feat..(r + 1) * feat];
            let row = &mut out[r * cols..(r + 1) * cols];
            row.copy_from_slice(bias);
            for (f, &xv) in xi.iter().enumerate() {
                let wr = &w[f * cols..(f + 1) * cols];
                for (z, &wv) in row.iter_mut().zip(wr) {
                    *z += xv * wv;
                }
            }
        }
    }

    pub fn rank1_acc(gw: &mut [f32], x: &[f32], g: &[f32], cols: usize) {
        for (f, &xv) in x.iter().enumerate() {
            let row = &mut gw[f * cols..(f + 1) * cols];
            for (z, &gv) in row.iter_mut().zip(g) {
                *z += gv * xv;
            }
        }
    }

    pub fn sgd_momentum(p: &mut [f32], m: &mut [f32], g: &[f32], lr: f32, mu: f32) {
        for ((p, m), &g) in p.iter_mut().zip(m.iter_mut()).zip(g) {
            *m = mu * *m + g;
            *p -= lr * *m;
        }
    }

    pub fn f16_encode(xs: &[f32], dst: &mut [u8]) {
        for (i, &x) in xs.iter().enumerate() {
            dst[2 * i..2 * i + 2].copy_from_slice(&f32_to_f16_bits(x).to_le_bytes());
        }
    }

    pub fn f16_decode(src: &[u8], dst: &mut [f32]) {
        for (i, c) in src.chunks_exact(2).enumerate() {
            dst[i] = f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]]));
        }
    }

    pub fn f32_write_le(xs: &[f32], dst: &mut [u8]) {
        for (i, &x) in xs.iter().enumerate() {
            dst[4 * i..4 * i + 4].copy_from_slice(&x.to_le_bytes());
        }
    }

    pub fn f32_read_le(src: &[u8], dst: &mut [f32]) {
        for (i, c) in src.chunks_exact(4).enumerate() {
            dst[i] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
        }
    }
}

// ------------------------------------------------------ avx2 backend

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    // Every function: 8-lane body + scalar tail performing the exact
    // per-element expression of the scalar backend, in the same operand
    // order.  SAFETY (all): caller guarantees the CPU has AVX2 (checked
    // once by `caps()`); unaligned loads/stores are used throughout, so
    // no alignment precondition; lane bounds are `i + 8 <= n`.

    #[target_feature(enable = "avx2")]
    pub unsafe fn fill(dst: &mut [f32], v: f32) {
        let n = dst.len();
        let vv = _mm256_set1_ps(v);
        let d = dst.as_mut_ptr();
        let mut i = 0;
        while i + 8 <= n {
            _mm256_storeu_ps(d.add(i), vv);
            i += 8;
        }
        while i < n {
            dst[i] = v;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn scale_in_place(dst: &mut [f32], alpha: f32) {
        let n = dst.len();
        let va = _mm256_set1_ps(alpha);
        let d = dst.as_mut_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let x = _mm256_loadu_ps(d.add(i));
            _mm256_storeu_ps(d.add(i), _mm256_mul_ps(x, va));
            i += 8;
        }
        while i < n {
            dst[i] *= alpha;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_in_place(dst: &mut [f32], alpha: f32, y: &[f32]) {
        let n = dst.len().min(y.len());
        let va = _mm256_set1_ps(alpha);
        let d = dst.as_mut_ptr();
        let s = y.as_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let x = _mm256_loadu_ps(d.add(i));
            let yv = _mm256_loadu_ps(s.add(i));
            // mul then add — an FMA would round once instead of twice
            // and diverge from the scalar bits.
            _mm256_storeu_ps(d.add(i), _mm256_add_ps(x, _mm256_mul_ps(va, yv)));
            i += 8;
        }
        while i < n {
            dst[i] += alpha * y[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_out(dst: &mut [f32], x: &[f32], alpha: f32, y: &[f32]) {
        let n = dst.len().min(x.len()).min(y.len());
        let va = _mm256_set1_ps(alpha);
        let d = dst.as_mut_ptr();
        let xs = x.as_ptr();
        let ys = y.as_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let xv = _mm256_loadu_ps(xs.add(i));
            let yv = _mm256_loadu_ps(ys.add(i));
            _mm256_storeu_ps(d.add(i), _mm256_add_ps(xv, _mm256_mul_ps(va, yv)));
            i += 8;
        }
        while i < n {
            dst[i] = x[i] + alpha * y[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn weighted_sum(dst: &mut [f32], a: &[f32], wa: f32, b: &[f32], wb: f32) {
        let n = dst.len().min(a.len()).min(b.len());
        let vwa = _mm256_set1_ps(wa);
        let vwb = _mm256_set1_ps(wb);
        let d = dst.as_mut_ptr();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let av = _mm256_loadu_ps(ap.add(i));
            let bv = _mm256_loadu_ps(bp.add(i));
            let t = _mm256_add_ps(_mm256_mul_ps(vwa, av), _mm256_mul_ps(vwb, bv));
            _mm256_storeu_ps(d.add(i), t);
            i += 8;
        }
        while i < n {
            dst[i] = wa * a[i] + wb * b[i];
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn delta_over_eta(dst: &mut [f32], a: &[f32], b: &[f32], eta: f32) {
        let n = dst.len().min(a.len()).min(b.len());
        let ve = _mm256_set1_ps(eta);
        let d = dst.as_mut_ptr();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let av = _mm256_loadu_ps(ap.add(i));
            let bv = _mm256_loadu_ps(bp.add(i));
            _mm256_storeu_ps(d.add(i), _mm256_div_ps(_mm256_sub_ps(av, bv), ve));
            i += 8;
        }
        while i < n {
            dst[i] = (a[i] - b[i]) / eta;
            i += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn gemm_bias(
        out: &mut [f32],
        x: &[f32],
        w: &[f32],
        bias: &[f32],
        rows: usize,
        feat: usize,
        cols: usize,
    ) {
        let op = out.as_mut_ptr();
        let xp = x.as_ptr();
        let wp = w.as_ptr();
        let bp = bias.as_ptr();
        for r in 0..rows {
            let xr = xp.add(r * feat);
            let or = op.add(r * cols);
            let mut c = 0;
            while c + 8 <= cols {
                // acc starts at the bias lane block; every feature adds
                // x[f]·w[f][c..c+8] as an explicit mul then add — the
                // same two roundings, in the same f order, as the
                // scalar accumulation.
                let mut acc = _mm256_loadu_ps(bp.add(c));
                for f in 0..feat {
                    let xv = _mm256_set1_ps(*xr.add(f));
                    let wv = _mm256_loadu_ps(wp.add(f * cols + c));
                    acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, wv));
                }
                _mm256_storeu_ps(or.add(c), acc);
                c += 8;
            }
            while c < cols {
                let mut z = bias[c];
                for f in 0..feat {
                    z += *xr.add(f) * *wp.add(f * cols + c);
                }
                *or.add(c) = z;
                c += 1;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn rank1_acc(gw: &mut [f32], x: &[f32], g: &[f32], cols: usize) {
        let gwp = gw.as_mut_ptr();
        let gp = g.as_ptr();
        for (f, &xv) in x.iter().enumerate() {
            let base = f * cols;
            let vx = _mm256_set1_ps(xv);
            let mut c = 0;
            while c + 8 <= cols {
                let gv = _mm256_loadu_ps(gp.add(c));
                let acc = _mm256_loadu_ps(gwp.add(base + c));
                _mm256_storeu_ps(
                    gwp.add(base + c),
                    _mm256_add_ps(acc, _mm256_mul_ps(gv, vx)),
                );
                c += 8;
            }
            while c < cols {
                *gwp.add(base + c) += g[c] * xv;
                c += 1;
            }
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn sgd_momentum(p: &mut [f32], m: &mut [f32], g: &[f32], lr: f32, mu: f32) {
        let n = p.len().min(m.len()).min(g.len());
        let vmu = _mm256_set1_ps(mu);
        let vlr = _mm256_set1_ps(lr);
        let pp = p.as_mut_ptr();
        let mp = m.as_mut_ptr();
        let gp = g.as_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let mv = _mm256_loadu_ps(mp.add(i));
            let gv = _mm256_loadu_ps(gp.add(i));
            let nm = _mm256_add_ps(_mm256_mul_ps(vmu, mv), gv);
            _mm256_storeu_ps(mp.add(i), nm);
            let pv = _mm256_loadu_ps(pp.add(i));
            _mm256_storeu_ps(pp.add(i), _mm256_sub_ps(pv, _mm256_mul_ps(vlr, nm)));
            i += 8;
        }
        while i < n {
            m[i] = mu * m[i] + g[i];
            p[i] -= lr * m[i];
            i += 1;
        }
    }

    /// f16 → f32 via the exact "magic multiply": expand the 15
    /// value bits into the f32 exponent/mantissa position and multiply
    /// by 2¹¹² (a power of two — exact for normals *and* subnormals),
    /// then blend in inf/NaN lanes rebuilt bit-by-bit exactly as the
    /// scalar decoder does (so signaling NaNs stay signaling).
    // The u8→__m128i pointer cast feeds an *unaligned* load intrinsic,
    // so the stricter pointee alignment is never relied upon.
    #[allow(clippy::cast_ptr_alignment)]
    #[target_feature(enable = "avx2")]
    pub unsafe fn f16_decode(src: &[u8], dst: &mut [f32]) {
        let n = dst.len().min(src.len() / 2);
        let magic = _mm256_castsi256_ps(_mm256_set1_epi32(0x7780_0000)); // 2^112
        let exp_mask = _mm256_set1_epi32(0x7C00);
        let d = dst.as_mut_ptr();
        let s = src.as_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let h = _mm_loadu_si128(s.add(2 * i) as *const __m128i);
            let hw = _mm256_cvtepu16_epi32(h);
            let sign =
                _mm256_slli_epi32::<16>(_mm256_and_si256(hw, _mm256_set1_epi32(0x8000)));
            let expmant =
                _mm256_slli_epi32::<13>(_mm256_and_si256(hw, _mm256_set1_epi32(0x7FFF)));
            let scaled = _mm256_mul_ps(_mm256_castsi256_ps(expmant), magic);
            let is_special =
                _mm256_cmpeq_epi32(_mm256_and_si256(hw, exp_mask), exp_mask);
            let special = _mm256_or_si256(
                _mm256_set1_epi32(0x7F80_0000),
                _mm256_slli_epi32::<13>(_mm256_and_si256(hw, _mm256_set1_epi32(0x03FF))),
            );
            let body =
                _mm256_blendv_epi8(_mm256_castps_si256(scaled), special, is_special);
            _mm256_storeu_ps(d.add(i), _mm256_castsi256_ps(_mm256_or_si256(body, sign)));
            i += 8;
        }
        while i < n {
            dst[i] = crate::util::f16::f16_bits_to_f32(u16::from_le_bytes([
                src[2 * i],
                src[2 * i + 1],
            ]));
            i += 1;
        }
    }
}

#[cfg(target_arch = "x86_64")]
mod f16c {
    use std::arch::x86_64::*;

    /// f32 → f16 through the hardware converter, explicitly pinned to
    /// round-to-nearest-even — the rounding `f32_to_f16_bits`
    /// implements in software (including subnormal results, overflow to
    /// ±inf and NaN quieting), so the lanes match the scalar bytes.
    /// SAFETY: caller guarantees AVX2+F16C (checked by `caps()`).
    // u8→__m128i cast feeds an unaligned store — alignment not relied on.
    #[allow(clippy::cast_ptr_alignment)]
    #[target_feature(enable = "avx2,f16c")]
    pub unsafe fn encode(xs: &[f32], dst: &mut [u8]) {
        // imm8[1:0] = rounding control (00 = nearest-even), imm8[2] = 0
        // so the immediate — not MXCSR — supplies the rounding.
        const RN: i32 = _MM_FROUND_TO_NEAREST_INT;
        let n = xs.len().min(dst.len() / 2);
        let s = xs.as_ptr();
        let d = dst.as_mut_ptr();
        let mut i = 0;
        while i + 8 <= n {
            let v = _mm256_loadu_ps(s.add(i));
            let h = _mm256_cvtps_ph::<RN>(v);
            _mm_storeu_si128(d.add(2 * i) as *mut __m128i, h);
            i += 8;
        }
        while i < n {
            let b = crate::util::f16::f32_to_f16_bits(xs[i]).to_le_bytes();
            dst[2 * i] = b[0];
            dst[2 * i + 1] = b[1];
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::f16::{f16_bits_to_f32, f32_to_f16_bits};
    use crate::util::rng::Xoshiro256pp;

    fn rand_vec(rng: &mut Xoshiro256pp, n: usize) -> Vec<f32> {
        (0..n).map(|_| (rng.normal() * 3.0) as f32).collect()
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    /// Lengths that exercise empty, single-lane, full-lane and
    /// remainder-lane dispatch edges.
    const EDGE_LENS: &[usize] = &[0, 1, 7, 8, 9, 15, 16, 17, 31, 100, 257];

    #[test]
    fn scalar_vs_simd_bit_identical_on_every_op() {
        if !simd_available() {
            return; // nothing to compare on this host
        }
        let mut rng = Xoshiro256pp::seed_from_u64(0x51D0);
        for &n in EDGE_LENS {
            let a = rand_vec(&mut rng, n);
            let b = rand_vec(&mut rng, n);
            let alpha = rng.normal() as f32;
            let (wa, wb) = (rng.normal() as f32, rng.normal() as f32);
            let eta = rng.uniform(0.001, 0.9) as f32;

            let run = |backend: Backend| -> Vec<Vec<u32>> {
                with_backend(backend, || {
                    let mut outs = Vec::new();
                    let mut d = a.clone();
                    axpy_in_place(&mut d, alpha, &b);
                    outs.push(bits(&d));
                    let mut d = vec![0.0; n];
                    axpy_out(&mut d, &a, alpha, &b);
                    outs.push(bits(&d));
                    let mut d = vec![0.0; n];
                    weighted_sum(&mut d, &a, wa, &b, wb);
                    outs.push(bits(&d));
                    let mut d = vec![0.0; n];
                    delta_over_eta(&mut d, &a, &b, eta);
                    outs.push(bits(&d));
                    let mut d = a.clone();
                    scale_in_place(&mut d, alpha);
                    outs.push(bits(&d));
                    let mut d = vec![1.0; n];
                    fill(&mut d, alpha);
                    outs.push(bits(&d));
                    outs
                })
            };
            assert_eq!(run(Backend::Scalar), run(Backend::Simd), "n={n}");
        }
    }

    #[test]
    fn worker_kernels_bit_identical_scalar_vs_simd() {
        if !simd_available() {
            return;
        }
        let mut rng = Xoshiro256pp::seed_from_u64(0x90B5);
        // Shapes cover single lanes, full 8-lane blocks and remainders
        // on the vectorized (column) axis.
        for &(rows, feat, cols) in &[
            (1usize, 1usize, 1usize),
            (3, 7, 8),
            (16, 32, 10),
            (2, 33, 17),
            (5, 8, 9),
        ] {
            let x = rand_vec(&mut rng, rows * feat);
            let w = rand_vec(&mut rng, feat * cols);
            let bias = rand_vec(&mut rng, cols);
            let g = rand_vec(&mut rng, cols);
            let p0 = rand_vec(&mut rng, feat * cols);
            let m0 = rand_vec(&mut rng, feat * cols);
            let (lr, mu) = (0.05f32, 0.9f32);

            let run = |backend: Backend| -> Vec<Vec<u32>> {
                with_backend(backend, || {
                    let mut fwd = vec![0.0f32; rows * cols];
                    gemm_bias(&mut fwd, &x, &w, &bias, rows, feat, cols);
                    let mut gw = vec![0.0f32; feat * cols];
                    for r in 0..rows {
                        rank1_acc(&mut gw, &x[r * feat..(r + 1) * feat], &g, cols);
                    }
                    let mut p = p0.clone();
                    let mut m = m0.clone();
                    sgd_momentum(&mut p, &mut m, &gw, lr, mu);
                    vec![bits(&fwd), bits(&gw), bits(&p), bits(&m)]
                })
            };
            assert_eq!(
                run(Backend::Scalar),
                run(Backend::Simd),
                "rows={rows} feat={feat} cols={cols}"
            );
        }
    }

    #[test]
    fn gemm_bias_matches_naive_reference() {
        let mut rng = Xoshiro256pp::seed_from_u64(0x6E44);
        let (rows, feat, cols) = (4usize, 6usize, 5usize);
        let x = rand_vec(&mut rng, rows * feat);
        let w = rand_vec(&mut rng, feat * cols);
        let bias = rand_vec(&mut rng, cols);
        let mut got = vec![0.0f32; rows * cols];
        gemm_bias(&mut got, &x, &w, &bias, rows, feat, cols);
        for r in 0..rows {
            for c in 0..cols {
                let mut z = bias[c];
                for f in 0..feat {
                    z += x[r * feat + f] * w[f * cols + c];
                }
                assert_eq!(got[r * cols + c].to_bits(), z.to_bits(), "({r},{c})");
            }
        }
    }

    #[test]
    fn f16_decode_simd_matches_scalar_for_all_65536_patterns() {
        if !simd_available() {
            return;
        }
        // Every f16 bit pattern, laid out so lanes + tail both run.
        let all: Vec<u8> = (0..=u16::MAX).flat_map(|h| h.to_le_bytes()).collect();
        let n = all.len() / 2;
        let mut want = vec![0.0f32; n];
        let mut got = vec![0.0f32; n];
        with_backend(Backend::Scalar, || f16_decode(&all, &mut want));
        with_backend(Backend::Simd, || f16_decode(&all, &mut got));
        for (i, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(w.to_bits(), g.to_bits(), "h={:#06x}", i as u16);
        }
    }

    #[test]
    fn f16_encode_simd_matches_scalar_incl_specials() {
        if !f16c_available() {
            return;
        }
        let mut xs: Vec<f32> = vec![
            0.0,
            -0.0,
            1.0,
            -1.0,
            65504.0,
            -65504.0,
            65520.0, // rounds up to inf
            1e10,
            -1e10,
            f32::INFINITY,
            f32::NEG_INFINITY,
            6.0e-8, // ~2⁻²⁴: rounds to the smallest subnormal
            1.0e-8, // below half the smallest subnormal → zero
            6.2e-5, // just inside the subnormal range
            f16_bits_to_f32(0x0001),
            f16_bits_to_f32(0x03FF),
            1.0 + 1.0 / 2048.0, // RTNE tie, stays even
            1.0 + 3.0 / 2048.0, // RTNE tie, rounds up to even
        ];
        let mut rng = Xoshiro256pp::seed_from_u64(0xF16C);
        for _ in 0..10_000 {
            let mag = 10f64.powf(rng.uniform(-9.0, 5.0));
            xs.push((rng.normal() * mag) as f32);
        }
        let mut want = vec![0u8; 2 * xs.len()];
        let mut got = vec![0u8; 2 * xs.len()];
        with_backend(Backend::Scalar, || f16_encode(&xs, &mut want));
        with_backend(Backend::Simd, || f16_encode(&xs, &mut got));
        assert_eq!(want, got);
        // NaN encodes to *a* NaN on both paths (payload equality is
        // additionally expected, but NaN-ness is the contract).
        let nan = [f32::NAN; 9];
        let mut wn = vec![0u8; 18];
        let mut gn = vec![0u8; 18];
        with_backend(Backend::Scalar, || f16_encode(&nan, &mut wn));
        with_backend(Backend::Simd, || f16_encode(&nan, &mut gn));
        for c in wn.chunks_exact(2).chain(gn.chunks_exact(2)) {
            let h = u16::from_le_bytes([c[0], c[1]]);
            assert!(f16_bits_to_f32(h).is_nan());
        }
    }

    #[test]
    fn f32_le_codec_roundtrips_and_matches_to_le_bytes() {
        let mut rng = Xoshiro256pp::seed_from_u64(0x1E);
        for &n in EDGE_LENS {
            let xs = rand_vec(&mut rng, n);
            let mut enc = vec![0u8; 4 * n];
            f32_write_le(&xs, &mut enc);
            let want: Vec<u8> = xs.iter().flat_map(|x| x.to_le_bytes()).collect();
            assert_eq!(enc, want);
            let mut dec = vec![0.0f32; n];
            f32_read_le(&enc, &mut dec);
            assert_eq!(bits(&xs), bits(&dec));
        }
    }

    #[test]
    fn force_scalar_env_and_override_resolution() {
        // The override wins over everything and restores cleanly.
        let before = active_backend();
        with_backend(Backend::Scalar, || {
            assert_eq!(active_backend(), Backend::Scalar);
        });
        assert_eq!(active_backend(), before);
        // Requesting SIMD clamps to what the CPU has.
        with_backend(Backend::Simd, || {
            let got = active_backend();
            if simd_available() {
                assert_eq!(got, Backend::Simd);
            } else {
                assert_eq!(got, Backend::Scalar);
            }
        });
        // Encode↔decode roundtrip through the dispatched codec agrees
        // with the pure-scalar converters.
        let xs: Vec<f32> = (0..1000).map(|i| (i as f32 - 500.0) * 0.01).collect();
        let mut enc = vec![0u8; 2 * xs.len()];
        f16_encode(&xs, &mut enc);
        let mut dec = vec![0.0f32; xs.len()];
        f16_decode(&enc, &mut dec);
        for (x, d) in xs.iter().zip(&dec) {
            let h = f32_to_f16_bits(*x);
            assert_eq!(d.to_bits(), f16_bits_to_f32(h).to_bits());
        }
    }
}
