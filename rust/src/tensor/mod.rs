//! Host-side tensor substrate: a flat `f32` buffer with a shape, plus
//! the vector arithmetic the parameter server's aggregation algebra
//! needs (Eqs. 1, 2, 5, 6).  Deliberately minimal — all FLOP-heavy math
//! happens inside the XLA executables; this type only carries model
//! state between them.
//!
//! The algebra comes in two flavours:
//!
//! * allocating (`weighted_sum`, `delta_over_eta`) — convenience
//!   wrappers that build a fresh [`ParamVec`];
//! * in-place / borrow-based (`axpy_into`, `scale_in_place`,
//!   `weighted_sum_into`, `delta_over_eta_into`, `copy_from`) — write
//!   into caller-provided buffers, typically leased from a
//!   [`BufferPool`], so the coordinator's steady-state aggregation
//!   performs **zero heap allocations** (see DESIGN.md §8).
//!
//! The allocating versions delegate to the `_into` versions, so both
//! are bit-identical by construction (enforced by property tests).
//!
//! Execution is two-level (DESIGN.md §12): every elementwise op runs
//! through the runtime-dispatched [`kernels`] (scalar ↔ AVX2, selected
//! once per process, `HERMES_FORCE_SCALAR` overridable) and, for large
//! buffers, fans its flat element range over [`shards`] workers —
//! bit-identical for any backend and any shard count because the ops
//! are elementwise (no FMA, no reassociation) and shards are disjoint.
//! The reductions (`l2_norm`, `relative_change`) deliberately stay
//! scalar-ordered: splitting or vectorizing a sum reassociates it and
//! changes the bits.

pub mod kernels;
pub mod shards;

use crate::util::f16;

/// Dense, row-major, f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} vs data len {}", data.len());
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn scalar(x: f32) -> Self {
        Self { shape: vec![], data: vec![x] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// A model's full parameter (or gradient) state as a list of tensors in
/// manifest order.  This is the unit the PS aggregates and the wire
/// ships.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ParamVec {
    pub tensors: Vec<Tensor>,
}

impl ParamVec {
    pub fn zeros_like(other: &ParamVec) -> ParamVec {
        ParamVec {
            tensors: other
                .tensors
                .iter()
                .map(|t| Tensor::zeros(t.shape().to_vec()))
                .collect(),
        }
    }

    pub fn from_shapes(shapes: &[Vec<usize>]) -> ParamVec {
        ParamVec {
            tensors: shapes.iter().map(|s| Tensor::zeros(s.clone())).collect(),
        }
    }

    pub fn num_elements(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    pub fn size_bytes(&self) -> usize {
        self.num_elements() * 4
    }

    /// Tensor-by-tensor shape equality (the precondition of every
    /// in-place operation's fast path).
    pub fn same_shape(&self, other: &ParamVec) -> bool {
        self.tensors.len() == other.tensors.len()
            && self
                .tensors
                .iter()
                .zip(&other.tensors)
                .all(|(a, b)| a.shape == b.shape)
    }

    /// Reshape `self` to match `like`, reusing existing allocations
    /// where possible.  Element values are unspecified afterwards —
    /// callers fully overwrite (the `_into` family) or [`fill`] first.
    /// No-op (and allocation-free) when shapes already match.
    ///
    /// [`fill`]: ParamVec::fill
    pub fn resize_like(&mut self, like: &ParamVec) {
        if self.same_shape(like) {
            return;
        }
        self.tensors.truncate(like.tensors.len());
        for (i, t) in like.tensors.iter().enumerate() {
            if let Some(mine) = self.tensors.get_mut(i) {
                mine.shape.clear();
                mine.shape.extend_from_slice(&t.shape);
                mine.data.resize(t.data.len(), 0.0);
            } else {
                self.tensors.push(Tensor::zeros(t.shape.clone()));
            }
        }
    }

    /// Set every element to `v` in place.
    pub fn fill(&mut self, v: f32) {
        let s = shards::shard_count(self.num_elements());
        if s > 1 {
            shards::run1(self, s, |d| kernels::fill(d, v));
        } else {
            for t in &mut self.tensors {
                kernels::fill(&mut t.data, v);
            }
        }
    }

    /// self ← other, reusing `self`'s allocations when shapes match.
    pub fn copy_from(&mut self, other: &ParamVec) {
        if !self.same_shape(other) {
            *self = other.clone();
            return;
        }
        let s = shards::shard_count(self.num_elements());
        if s > 1 {
            shards::run2(self, other, s, kernels::copy);
        } else {
            for (a, b) in self.tensors.iter_mut().zip(&other.tensors) {
                a.data.copy_from_slice(&b.data);
            }
        }
    }

    /// self ← self + alpha · other   (shape-checked axpy).
    pub fn axpy(&mut self, alpha: f32, other: &ParamVec) {
        assert_eq!(self.tensors.len(), other.tensors.len());
        let s = shards::shard_count(self.num_elements());
        if s > 1 {
            debug_assert!(self.same_shape(other));
            shards::run2(self, other, s, move |d, y| {
                kernels::axpy_in_place(d, alpha, y)
            });
        } else {
            for (a, b) in self.tensors.iter_mut().zip(&other.tensors) {
                debug_assert_eq!(a.shape(), b.shape());
                kernels::axpy_in_place(&mut a.data, alpha, &b.data);
            }
        }
    }

    /// out ← self + alpha · other — the borrow-based axpy: `self` stays
    /// untouched and `out` (typically pool-leased) absorbs the result.
    pub fn axpy_into(&self, alpha: f32, other: &ParamVec, out: &mut ParamVec) {
        assert_eq!(self.tensors.len(), other.tensors.len());
        out.resize_like(self);
        let s = shards::shard_count(self.num_elements());
        if s > 1 {
            debug_assert!(self.same_shape(other));
            shards::run3(out, self, other, s, move |z, x, y| {
                kernels::axpy_out(z, x, alpha, y)
            });
        } else {
            for ((a, b), o) in
                self.tensors.iter().zip(&other.tensors).zip(&mut out.tensors)
            {
                debug_assert_eq!(a.shape(), b.shape());
                kernels::axpy_out(&mut o.data, &a.data, alpha, &b.data);
            }
        }
    }

    /// self ← alpha · self (renamed from `scale`, which was already
    /// in place; one name, no allocating twin).
    pub fn scale_in_place(&mut self, alpha: f32) {
        let s = shards::shard_count(self.num_elements());
        if s > 1 {
            shards::run1(self, s, move |d| kernels::scale_in_place(d, alpha));
        } else {
            for t in &mut self.tensors {
                kernels::scale_in_place(&mut t.data, alpha);
            }
        }
    }

    /// out ← wa·a + wb·b — the loss-weighted aggregation core of Eq. 6,
    /// writing into a caller-provided buffer.
    pub fn weighted_sum_into(a: &ParamVec, wa: f32, b: &ParamVec, wb: f32, out: &mut ParamVec) {
        assert_eq!(a.tensors.len(), b.tensors.len());
        out.resize_like(a);
        let s = shards::shard_count(a.num_elements());
        if s > 1 {
            debug_assert!(a.same_shape(b));
            shards::run3(out, a, b, s, move |z, x, y| {
                kernels::weighted_sum(z, x, wa, y, wb)
            });
        } else {
            for ((ta, tb), to) in a.tensors.iter().zip(&b.tensors).zip(&mut out.tensors) {
                debug_assert_eq!(ta.shape(), tb.shape());
                kernels::weighted_sum(&mut to.data, &ta.data, wa, &tb.data, wb);
            }
        }
    }

    /// Out-of-place weighted sum (allocating wrapper over
    /// [`ParamVec::weighted_sum_into`] — bit-identical results).
    pub fn weighted_sum(a: &ParamVec, wa: f32, b: &ParamVec, wb: f32) -> ParamVec {
        let mut out = ParamVec::default();
        ParamVec::weighted_sum_into(a, wa, b, wb, &mut out);
        out
    }

    /// out ← (self − other) / eta  — the cumulative-gradient recovery
    /// the worker performs to report `G` (Alg. 2's Worker-SGD
    /// accumulates gradient steps; dividing the parameter delta by η
    /// recovers the same sum, including momentum contributions).
    pub fn delta_over_eta_into(&self, other: &ParamVec, eta: f32, out: &mut ParamVec) {
        assert!(eta != 0.0);
        assert_eq!(self.tensors.len(), other.tensors.len());
        out.resize_like(self);
        let s = shards::shard_count(self.num_elements());
        if s > 1 {
            debug_assert!(self.same_shape(other));
            shards::run3(out, self, other, s, move |z, x, y| {
                kernels::delta_over_eta(z, x, y, eta)
            });
        } else {
            for ((a, b), o) in
                self.tensors.iter().zip(&other.tensors).zip(&mut out.tensors)
            {
                debug_assert_eq!(a.shape(), b.shape());
                kernels::delta_over_eta(&mut o.data, &a.data, &b.data, eta);
            }
        }
    }

    /// d = (self − other) / eta (allocating wrapper over
    /// [`ParamVec::delta_over_eta_into`] — bit-identical results).
    pub fn delta_over_eta(&self, other: &ParamVec, eta: f32) -> ParamVec {
        let mut out = ParamVec::default();
        self.delta_over_eta_into(other, eta, &mut out);
        out
    }

    /// L2 norm over all elements.
    ///
    /// Deliberately **scalar-ordered** — excluded from the SIMD/shard
    /// layers: a reduction only vectorizes/parallelizes by splitting
    /// the sum into partial sums, which reassociates the additions and
    /// changes the result bits.  Elementwise ops have no such term
    /// ordering, which is why they can fan out and reductions cannot
    /// (DESIGN.md §12; pinned by `prop_reductions_pinned_scalar` in
    /// `tests/coordinator_props.rs`).
    pub fn l2_norm(&self) -> f64 {
        self.tensors
            .iter()
            .flat_map(|t| t.data())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }

    /// Relative change ‖a−b‖/‖b‖ — SelSync's gate metric (§II-E).
    /// Scalar-ordered for the same reason as [`ParamVec::l2_norm`].
    pub fn relative_change(a: &ParamVec, b: &ParamVec) -> f64 {
        let denom = b.l2_norm().max(1e-12);
        let mut num = 0.0f64;
        for (ta, tb) in a.tensors.iter().zip(&b.tensors) {
            for (x, y) in ta.data().iter().zip(tb.data()) {
                num += ((x - y) as f64).powi(2);
            }
        }
        num.sqrt() / denom
    }

    /// fp16 wire encoding (shape info travels in the wire header).
    pub fn encode_f16(&self) -> Vec<Vec<u8>> {
        self.tensors.iter().map(|t| f16::encode_f16(t.data())).collect()
    }

    /// Decode an fp16 payload against known shapes.
    pub fn decode_f16(shapes: &[Vec<usize>], payloads: &[Vec<u8>]) -> ParamVec {
        assert_eq!(shapes.len(), payloads.len());
        ParamVec {
            tensors: shapes
                .iter()
                .zip(payloads)
                .map(|(s, p)| Tensor::new(s.clone(), f16::decode_f16(p)))
                .collect(),
        }
    }
}

/// Reusable [`ParamVec`] scratch buffers for the coordinator hot path.
///
/// The aggregation state machines (PS algebra, framework drivers) lease
/// buffers with [`acquire_like`], write via the `_into` algebra, and
/// [`release`] them when the message is fully processed.  After warmup
/// every lease is satisfied from the free list and `resize_like` is a
/// no-op, so steady-state rounds allocate nothing (asserted by
/// `tests/alloc_hotpath.rs` with a counting global allocator).
///
/// Growth is bounded: at most [`BufferPool::DEFAULT_MAX_PARKED`]
/// buffers park on the free list (override with
/// [`with_max_parked`]); a `release` beyond the cap drops the buffer
/// instead of hoarding it.  Without the cap, churned runs (rejoin →
/// `resize_like` over ever-bigger shapes) grow the free list without
/// bound.  [`trim`] additionally releases already-parked memory after
/// a peak (e.g. once a churn burst settles).
///
/// [`acquire_like`]: BufferPool::acquire_like
/// [`release`]: BufferPool::release
/// [`with_max_parked`]: BufferPool::with_max_parked
/// [`trim`]: BufferPool::trim
#[derive(Debug)]
pub struct BufferPool {
    free: Vec<ParamVec>,
    max_parked: usize,
}

impl Default for BufferPool {
    fn default() -> BufferPool {
        BufferPool::new()
    }
}

impl BufferPool {
    /// Most buffers a pool parks by default — a dozen leases per round
    /// across all six drivers, doubled for headroom.
    pub const DEFAULT_MAX_PARKED: usize = 32;

    pub fn new() -> BufferPool {
        BufferPool {
            free: Vec::new(),
            max_parked: Self::DEFAULT_MAX_PARKED,
        }
    }

    /// A pool that parks at most `max_parked` buffers.
    pub fn with_max_parked(max_parked: usize) -> BufferPool {
        BufferPool { free: Vec::new(), max_parked }
    }

    /// Buffers currently parked in the pool.
    pub fn available(&self) -> usize {
        self.free.len()
    }

    /// The parked-buffer cap.
    pub fn max_parked(&self) -> usize {
        self.max_parked
    }

    /// Lease a buffer shaped like `like`; element values unspecified.
    pub fn acquire_like(&mut self, like: &ParamVec) -> ParamVec {
        let mut pv = self.free.pop().unwrap_or_default();
        pv.resize_like(like);
        pv
    }

    /// Lease a zero-filled buffer shaped like `like`.
    pub fn acquire_zeroed_like(&mut self, like: &ParamVec) -> ParamVec {
        let mut pv = self.acquire_like(like);
        pv.fill(0.0);
        pv
    }

    /// Return a leased buffer for reuse.  Dropped (freed) instead of
    /// parked when the pool is already holding `max_parked` buffers.
    pub fn release(&mut self, pv: ParamVec) {
        if self.free.len() < self.max_parked {
            self.free.push(pv);
        }
    }

    /// Shrink to at most `keep` parked buffers and give the excess —
    /// plus the free list's own spare capacity — back to the
    /// allocator.
    pub fn trim(&mut self, keep: usize) {
        self.free.truncate(keep);
        self.free.shrink_to_fit();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn pv(vals: &[&[f32]]) -> ParamVec {
        ParamVec {
            tensors: vals
                .iter()
                .map(|v| Tensor::new(vec![v.len()], v.to_vec()))
                .collect(),
        }
    }

    fn rand_pv(rng: &mut Xoshiro256pp) -> ParamVec {
        let n_tensors = 1 + rng.next_below(4) as usize;
        ParamVec {
            tensors: (0..n_tensors)
                .map(|_| {
                    let n = 1 + rng.next_below(96) as usize;
                    Tensor::new(
                        vec![n],
                        (0..n).map(|_| (rng.normal() * 2.0) as f32).collect(),
                    )
                })
                .collect(),
        }
    }

    fn bits(p: &ParamVec) -> Vec<u32> {
        p.tensors
            .iter()
            .flat_map(|t| t.data().iter().map(|x| x.to_bits()))
            .collect()
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = pv(&[&[1.0, 2.0], &[3.0]]);
        let b = pv(&[&[10.0, 20.0], &[30.0]]);
        a.axpy(0.5, &b);
        assert_eq!(a, pv(&[&[6.0, 12.0], &[18.0]]));
        a.scale_in_place(2.0);
        assert_eq!(a, pv(&[&[12.0, 24.0], &[36.0]]));
    }

    #[test]
    fn weighted_sum_is_convex_combination_when_weights_normalized() {
        let a = pv(&[&[2.0, 4.0]]);
        let b = pv(&[&[4.0, 8.0]]);
        let c = ParamVec::weighted_sum(&a, 0.25, &b, 0.75);
        assert_eq!(c, pv(&[&[3.5, 7.0]]));
    }

    #[test]
    fn delta_over_eta_recovers_gradient_sum() {
        // w_new = w_old − η·g  ⇒  (w_old − w_new)/η = g.
        let w_old = pv(&[&[1.0, 2.0]]);
        let mut w_new = w_old.clone();
        let g = pv(&[&[0.5, -0.25]]);
        w_new.axpy(-0.1, &g); // one SGD step, η = 0.1
        let rec = w_old.delta_over_eta(&w_new, 0.1);
        for (a, b) in rec.tensors[0].data().iter().zip(g.tensors[0].data()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn l2_norm_and_relative_change() {
        let a = pv(&[&[3.0], &[4.0]]);
        assert!((a.l2_norm() - 5.0).abs() < 1e-12);
        let b = pv(&[&[3.0], &[4.0]]);
        assert_eq!(ParamVec::relative_change(&a, &b), 0.0);
        let c = pv(&[&[6.0], &[8.0]]);
        assert!((ParamVec::relative_change(&c, &a) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn f16_roundtrip_within_tolerance() {
        let a = pv(&[&[0.125, -3.75, 100.0], &[1e-3]]);
        let shapes: Vec<Vec<usize>> =
            a.tensors.iter().map(|t| t.shape().to_vec()).collect();
        let enc = a.encode_f16();
        let dec = ParamVec::decode_f16(&shapes, &enc);
        for (ta, tb) in a.tensors.iter().zip(&dec.tensors) {
            for (x, y) in ta.data().iter().zip(tb.data()) {
                assert!((x - y).abs() <= x.abs() * 0.001 + 1e-4);
            }
        }
        // Wire bytes are half of f32.
        let total: usize = enc.iter().map(|v| v.len()).sum();
        assert_eq!(total, a.size_bytes() / 2);
    }

    #[test]
    fn zeros_like_matches_shapes() {
        let a = pv(&[&[1.0, 2.0], &[3.0]]);
        let z = ParamVec::zeros_like(&a);
        assert_eq!(z.num_elements(), 3);
        assert!(z.tensors.iter().all(|t| t.data().iter().all(|&x| x == 0.0)));
    }

    // ------------------------------- in-place algebra property tests

    #[test]
    fn prop_axpy_into_bit_identical_to_clone_then_axpy() {
        for seed in 0..200 {
            let mut rng = Xoshiro256pp::seed_from_u64(seed);
            let a = rand_pv(&mut rng);
            let b = {
                let mut b = ParamVec::zeros_like(&a);
                for t in &mut b.tensors {
                    for v in t.data_mut() {
                        *v = (rng.normal() * 2.0) as f32;
                    }
                }
                b
            };
            let alpha = rng.normal() as f32;
            let mut want = a.clone();
            want.axpy(alpha, &b);
            let mut got = ParamVec::default();
            a.axpy_into(alpha, &b, &mut got);
            assert_eq!(bits(&want), bits(&got), "seed {seed}");
        }
    }

    #[test]
    fn prop_weighted_sum_into_bit_identical_to_allocating() {
        for seed in 0..200 {
            let mut rng = Xoshiro256pp::seed_from_u64(1000 + seed);
            let a = rand_pv(&mut rng);
            let mut b = ParamVec::zeros_like(&a);
            for t in &mut b.tensors {
                for v in t.data_mut() {
                    *v = (rng.normal() * 2.0) as f32;
                }
            }
            let (wa, wb) = (rng.normal() as f32, rng.normal() as f32);
            let want = ParamVec::weighted_sum(&a, wa, &b, wb);
            // Dirty, differently-shaped out buffer: must still match.
            let mut got = pv(&[&[9.0; 3]]);
            ParamVec::weighted_sum_into(&a, wa, &b, wb, &mut got);
            assert_eq!(bits(&want), bits(&got), "seed {seed}");
        }
    }

    #[test]
    fn prop_delta_over_eta_into_bit_identical_to_allocating() {
        for seed in 0..200 {
            let mut rng = Xoshiro256pp::seed_from_u64(2000 + seed);
            let a = rand_pv(&mut rng);
            let mut b = ParamVec::zeros_like(&a);
            for t in &mut b.tensors {
                for v in t.data_mut() {
                    *v = (rng.normal() * 2.0) as f32;
                }
            }
            let eta = (rng.uniform(0.001, 0.9)) as f32;
            let want = a.delta_over_eta(&b, eta);
            let mut got = ParamVec::default();
            a.delta_over_eta_into(&b, eta, &mut got);
            assert_eq!(bits(&want), bits(&got), "seed {seed}");
        }
    }

    #[test]
    fn scale_in_place_scales_every_element() {
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let a = rand_pv(&mut rng);
        let mut x = a.clone();
        x.scale_in_place(0.37);
        for (got, orig) in x
            .tensors
            .iter()
            .flat_map(|t| t.data())
            .zip(a.tensors.iter().flat_map(|t| t.data()))
        {
            assert_eq!(got.to_bits(), (0.37f32 * orig).to_bits());
        }
    }

    #[test]
    fn copy_from_and_resize_like_reuse_allocations() {
        let a = pv(&[&[1.0, 2.0, 3.0], &[4.0]]);
        let mut dst = pv(&[&[9.0, 9.0, 9.0], &[9.0]]);
        let ptr = dst.tensors[0].data().as_ptr();
        dst.copy_from(&a);
        assert_eq!(dst, a);
        assert_eq!(dst.tensors[0].data().as_ptr(), ptr, "copy_from reallocated");
        // Shape mismatch falls back to a clone.
        let mut small = pv(&[&[0.0]]);
        small.copy_from(&a);
        assert_eq!(small, a);
        // resize_like preserves buffers when shapes already match.
        let mut buf = a.clone();
        let ptr = buf.tensors[0].data().as_ptr();
        buf.resize_like(&a);
        assert_eq!(buf.tensors[0].data().as_ptr(), ptr);
        assert!(buf.same_shape(&a));
    }

    #[test]
    fn buffer_pool_reuses_released_buffers() {
        let like = pv(&[&[1.0, 2.0], &[3.0, 4.0, 5.0]]);
        let mut pool = BufferPool::new();
        let b1 = pool.acquire_like(&like);
        assert!(b1.same_shape(&like));
        let ptr = b1.tensors[0].data().as_ptr();
        pool.release(b1);
        assert_eq!(pool.available(), 1);
        // Same shape ⇒ the parked buffer comes back untouched.
        let b2 = pool.acquire_like(&like);
        assert_eq!(b2.tensors[0].data().as_ptr(), ptr);
        assert_eq!(pool.available(), 0);
        pool.release(b2);
        // Zeroed lease really is zeroed even after dirty writes.
        let mut dirty = pool.acquire_like(&like);
        dirty.fill(7.0);
        pool.release(dirty);
        let z = pool.acquire_zeroed_like(&like);
        assert!(z.tensors.iter().all(|t| t.data().iter().all(|&x| x == 0.0)));
    }

    #[test]
    fn buffer_pool_caps_growth_and_trims() {
        let like = pv(&[&[1.0, 2.0, 3.0]]);
        let mut pool = BufferPool::with_max_parked(3);
        assert_eq!(pool.max_parked(), 3);
        // Churn simulation: release more buffers than the cap.
        for _ in 0..10 {
            let b = ParamVec::zeros_like(&like);
            pool.release(b);
        }
        assert_eq!(pool.available(), 3, "release beyond the cap must drop");
        // Leases drain and refill without exceeding the cap.
        let b = pool.acquire_like(&like);
        assert_eq!(pool.available(), 2);
        pool.release(b);
        assert_eq!(pool.available(), 3);
        // Trim shrinks the parked set (post-churn-peak memory release).
        pool.trim(1);
        assert_eq!(pool.available(), 1);
        pool.trim(0);
        assert_eq!(pool.available(), 0);
        // The default pool carries the documented cap.
        assert_eq!(BufferPool::new().max_parked(), BufferPool::DEFAULT_MAX_PARKED);
    }

    #[test]
    fn ops_bit_identical_across_backends_and_shard_counts() {
        // The in-place algebra must produce the same bits whether it
        // runs scalar, SIMD, inline or sharded — including empty
        // tensors, single elements and `len % 8 != 0` remainders.
        use kernels::Backend;
        let shapes: &[&[usize]] = &[&[0, 5, 1], &[9], &[8, 8], &[3, 0, 100]];
        for (case, lens) in shapes.iter().enumerate() {
            let mut rng = Xoshiro256pp::seed_from_u64(77 + case as u64);
            let mk = |rng: &mut Xoshiro256pp| ParamVec {
                tensors: lens
                    .iter()
                    .map(|&n| {
                        Tensor::new(
                            vec![n],
                            (0..n).map(|_| (rng.normal() * 2.0) as f32).collect(),
                        )
                    })
                    .collect(),
            };
            let a = mk(&mut rng);
            let b = mk(&mut rng);
            let alpha = rng.normal() as f32;
            let eta = rng.uniform(0.01, 0.9) as f32;

            let run = |backend: Backend, s: usize| -> Vec<Vec<u32>> {
                kernels::with_backend(backend, || {
                    shards::with_shards(s, || {
                        let mut outs = Vec::new();
                        let mut o = ParamVec::default();
                        a.axpy_into(alpha, &b, &mut o);
                        outs.push(bits(&o));
                        ParamVec::weighted_sum_into(&a, 0.3, &b, 0.7, &mut o);
                        outs.push(bits(&o));
                        a.delta_over_eta_into(&b, eta, &mut o);
                        outs.push(bits(&o));
                        let mut x = a.clone();
                        x.axpy(alpha, &b);
                        outs.push(bits(&x));
                        x.scale_in_place(alpha);
                        outs.push(bits(&x));
                        x.copy_from(&b);
                        outs.push(bits(&x));
                        x.fill(alpha);
                        outs.push(bits(&x));
                        outs
                    })
                })
            };
            let want = run(Backend::Scalar, 1);
            for s in [1usize, 3, 4, 7] {
                assert_eq!(want, run(Backend::Scalar, s), "scalar s={s} case {case}");
                assert_eq!(want, run(Backend::Simd, s), "simd s={s} case {case}");
            }
        }
    }
}
