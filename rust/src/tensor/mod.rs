//! Host-side tensor substrate: a flat `f32` buffer with a shape, plus
//! the vector arithmetic the parameter server's aggregation algebra
//! needs (Eqs. 1, 2, 5, 6).  Deliberately minimal — all FLOP-heavy math
//! happens inside the XLA executables; this type only carries model
//! state between them.

use crate::util::f16;

/// Dense, row-major, f32 tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} vs data len {}", data.len());
        Self { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        Self { shape, data: vec![0.0; n] }
    }

    pub fn scalar(x: f32) -> Self {
        Self { shape: vec![], data: vec![x] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

/// A model's full parameter (or gradient) state as a list of tensors in
/// manifest order.  This is the unit the PS aggregates and the wire
/// ships.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ParamVec {
    pub tensors: Vec<Tensor>,
}

impl ParamVec {
    pub fn zeros_like(other: &ParamVec) -> ParamVec {
        ParamVec {
            tensors: other
                .tensors
                .iter()
                .map(|t| Tensor::zeros(t.shape().to_vec()))
                .collect(),
        }
    }

    pub fn from_shapes(shapes: &[Vec<usize>]) -> ParamVec {
        ParamVec {
            tensors: shapes.iter().map(|s| Tensor::zeros(s.clone())).collect(),
        }
    }

    pub fn num_elements(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    pub fn size_bytes(&self) -> usize {
        self.num_elements() * 4
    }

    /// self ← self + alpha · other   (shape-checked axpy).
    pub fn axpy(&mut self, alpha: f32, other: &ParamVec) {
        assert_eq!(self.tensors.len(), other.tensors.len());
        for (a, b) in self.tensors.iter_mut().zip(&other.tensors) {
            debug_assert_eq!(a.shape(), b.shape());
            for (x, y) in a.data_mut().iter_mut().zip(b.data()) {
                *x += alpha * y;
            }
        }
    }

    /// self ← alpha · self.
    pub fn scale(&mut self, alpha: f32) {
        for t in &mut self.tensors {
            for x in t.data_mut() {
                *x *= alpha;
            }
        }
    }

    /// Out-of-place weighted sum: `wa·a + wb·b` — the loss-weighted
    /// aggregation core of Eq. 6.
    pub fn weighted_sum(a: &ParamVec, wa: f32, b: &ParamVec, wb: f32) -> ParamVec {
        assert_eq!(a.tensors.len(), b.tensors.len());
        ParamVec {
            tensors: a
                .tensors
                .iter()
                .zip(&b.tensors)
                .map(|(ta, tb)| {
                    debug_assert_eq!(ta.shape(), tb.shape());
                    Tensor::new(
                        ta.shape().to_vec(),
                        ta.data()
                            .iter()
                            .zip(tb.data())
                            .map(|(x, y)| wa * x + wb * y)
                            .collect(),
                    )
                })
                .collect(),
        }
    }

    /// d = (self − other) / eta  — the cumulative-gradient recovery the
    /// worker performs to report `G` (Alg. 2's Worker-SGD accumulates
    /// gradient steps; dividing the parameter delta by η recovers the
    /// same sum, including momentum contributions).
    pub fn delta_over_eta(&self, other: &ParamVec, eta: f32) -> ParamVec {
        assert!(eta != 0.0);
        assert_eq!(self.tensors.len(), other.tensors.len());
        ParamVec {
            tensors: self
                .tensors
                .iter()
                .zip(&other.tensors)
                .map(|(a, b)| {
                    Tensor::new(
                        a.shape().to_vec(),
                        a.data()
                            .iter()
                            .zip(b.data())
                            .map(|(x, y)| (x - y) / eta)
                            .collect(),
                    )
                })
                .collect(),
        }
    }

    /// L2 norm over all elements.
    pub fn l2_norm(&self) -> f64 {
        self.tensors
            .iter()
            .flat_map(|t| t.data())
            .map(|&x| (x as f64) * (x as f64))
            .sum::<f64>()
            .sqrt()
    }

    /// Relative change ‖a−b‖/‖b‖ — SelSync's gate metric (§II-E).
    pub fn relative_change(a: &ParamVec, b: &ParamVec) -> f64 {
        let denom = b.l2_norm().max(1e-12);
        let mut num = 0.0f64;
        for (ta, tb) in a.tensors.iter().zip(&b.tensors) {
            for (x, y) in ta.data().iter().zip(tb.data()) {
                num += ((x - y) as f64).powi(2);
            }
        }
        num.sqrt() / denom
    }

    /// fp16 wire encoding (shape info travels in the wire header).
    pub fn encode_f16(&self) -> Vec<Vec<u8>> {
        self.tensors.iter().map(|t| f16::encode_f16(t.data())).collect()
    }

    /// Decode an fp16 payload against known shapes.
    pub fn decode_f16(shapes: &[Vec<usize>], payloads: &[Vec<u8>]) -> ParamVec {
        assert_eq!(shapes.len(), payloads.len());
        ParamVec {
            tensors: shapes
                .iter()
                .zip(payloads)
                .map(|(s, p)| Tensor::new(s.clone(), f16::decode_f16(p)))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pv(vals: &[&[f32]]) -> ParamVec {
        ParamVec {
            tensors: vals
                .iter()
                .map(|v| Tensor::new(vec![v.len()], v.to_vec()))
                .collect(),
        }
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = pv(&[&[1.0, 2.0], &[3.0]]);
        let b = pv(&[&[10.0, 20.0], &[30.0]]);
        a.axpy(0.5, &b);
        assert_eq!(a, pv(&[&[6.0, 12.0], &[18.0]]));
        a.scale(2.0);
        assert_eq!(a, pv(&[&[12.0, 24.0], &[36.0]]));
    }

    #[test]
    fn weighted_sum_is_convex_combination_when_weights_normalized() {
        let a = pv(&[&[2.0, 4.0]]);
        let b = pv(&[&[4.0, 8.0]]);
        let c = ParamVec::weighted_sum(&a, 0.25, &b, 0.75);
        assert_eq!(c, pv(&[&[3.5, 7.0]]));
    }

    #[test]
    fn delta_over_eta_recovers_gradient_sum() {
        // w_new = w_old − η·g  ⇒  (w_old − w_new)/η = g.
        let w_old = pv(&[&[1.0, 2.0]]);
        let mut w_new = w_old.clone();
        let g = pv(&[&[0.5, -0.25]]);
        w_new.axpy(-0.1, &g); // one SGD step, η = 0.1
        let rec = w_old.delta_over_eta(&w_new, 0.1);
        for (a, b) in rec.tensors[0].data().iter().zip(g.tensors[0].data()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn l2_norm_and_relative_change() {
        let a = pv(&[&[3.0], &[4.0]]);
        assert!((a.l2_norm() - 5.0).abs() < 1e-12);
        let b = pv(&[&[3.0], &[4.0]]);
        assert_eq!(ParamVec::relative_change(&a, &b), 0.0);
        let c = pv(&[&[6.0], &[8.0]]);
        assert!((ParamVec::relative_change(&c, &a) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn f16_roundtrip_within_tolerance() {
        let a = pv(&[&[0.125, -3.75, 100.0], &[1e-3]]);
        let shapes: Vec<Vec<usize>> =
            a.tensors.iter().map(|t| t.shape().to_vec()).collect();
        let enc = a.encode_f16();
        let dec = ParamVec::decode_f16(&shapes, &enc);
        for (ta, tb) in a.tensors.iter().zip(&dec.tensors) {
            for (x, y) in ta.data().iter().zip(tb.data()) {
                assert!((x - y).abs() <= x.abs() * 0.001 + 1e-4);
            }
        }
        // Wire bytes are half of f32.
        let total: usize = enc.iter().map(|v| v.len()).sum();
        assert_eq!(total, a.size_bytes() / 2);
    }

    #[test]
    fn zeros_like_matches_shapes() {
        let a = pv(&[&[1.0, 2.0], &[3.0]]);
        let z = ParamVec::zeros_like(&a);
        assert_eq!(z.num_elements(), 3);
        assert!(z.tensors.iter().all(|t| t.data().iter().all(|&x| x == 0.0)));
    }
}
