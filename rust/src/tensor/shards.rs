//! Sharded parallel execution for the aggregation algebra
//! (DESIGN.md §12).  A [`ParamVec`]'s *flat* element range (all tensors
//! concatenated in manifest order) is split into `S` contiguous,
//! disjoint shards; each shard is a list of `&mut [f32]` pieces (a
//! shard may straddle tensor boundaries).  Shards are processed on
//! `std::thread::scope` workers running the dispatched
//! [`kernels`](super::kernels) on their pieces.
//!
//! **Determinism.**  Shards never overlap and every kernel is
//! elementwise, so each output element is written exactly once by
//! exactly one worker computing the exact scalar expression — results
//! are bit-identical for *any* shard count and any thread schedule.
//! Reductions (`l2_norm`, `relative_change`) are excluded: splitting a
//! sum reassociates it and changes the bits (DESIGN.md §12).
//!
//! **Shard-count policy.**  `shard_count(len)` returns 1 (inline, no
//! threads, no allocation — the regime `tests/alloc_hotpath.rs` pins)
//! below [`SHARD_MIN_ELEMS`]·2, else scales with the buffer size up to
//! `min(cores, MAX_SHARDS)`.  `HERMES_SHARDS=N` pins it globally;
//! [`with_shards`] pins it for a closure (tests/benches).  Sharded
//! calls pay a scoped-thread setup (spawn + join + piece lists, heap
//! included) that only amortizes on multi-hundred-KB tensors — which is
//! exactly when the policy turns it on.

use std::cell::Cell;
use std::sync::OnceLock;

use super::kernels;
use super::ParamVec;

/// Below twice this many elements a buffer is processed inline.
pub const SHARD_MIN_ELEMS: usize = 1 << 16;

/// Upper bound on auto-selected shards (beyond ~8 the memory bus, not
/// the cores, is the limit for these streaming kernels).
pub const MAX_SHARDS: usize = 8;

thread_local! {
    /// Per-thread test/bench override; `usize::MAX` = no override.
    /// Thread-local for the same reason as the kernel-backend override:
    /// concurrently running tests force different shard counts without
    /// racing each other.
    static OVERRIDE: Cell<usize> = const { Cell::new(usize::MAX) };
}

fn env_shards() -> Option<usize> {
    static E: OnceLock<Option<usize>> = OnceLock::new();
    *E.get_or_init(|| {
        std::env::var("HERMES_SHARDS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&s| s >= 1)
    })
}

fn hw_threads() -> usize {
    static T: OnceLock<usize> = OnceLock::new();
    *T.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// How many shards a buffer of `len` elements runs on right now (on
/// this thread — see the override note above).
pub fn shard_count(len: usize) -> usize {
    let forced = OVERRIDE.with(|c| c.get());
    if forced != usize::MAX {
        return forced.max(1);
    }
    if let Some(s) = env_shards() {
        return s;
    }
    if len < 2 * SHARD_MIN_ELEMS {
        return 1;
    }
    (len / SHARD_MIN_ELEMS).min(hw_threads()).min(MAX_SHARDS)
}

/// Run `f` with this thread's shard count pinned to `s` (≥1),
/// restoring the previous policy afterwards.  Like
/// [`kernels::with_backend`](super::kernels::with_backend) this is a
/// perf knob only: every shard count computes identical bits.
pub fn with_shards<R>(s: usize, f: impl FnOnce() -> R) -> R {
    let prev = OVERRIDE.with(|c| c.replace(s.max(1)));
    let out = f();
    OVERRIDE.with(|c| c.set(prev));
    out
}

/// `s+1` cumulative boundaries of an even `n`-element split: shard `i`
/// owns `[bounds[i], bounds[i+1])`; the first `n % s` shards take the
/// remainder element each.
pub fn shard_bounds(n: usize, s: usize) -> Vec<usize> {
    let s = s.max(1);
    let base = n / s;
    let rem = n % s;
    let mut bounds = Vec::with_capacity(s + 1);
    bounds.push(0);
    let mut acc = 0;
    for i in 0..s {
        acc += base + usize::from(i < rem);
        bounds.push(acc);
    }
    bounds
}

/// Split `pv`'s flat range at `bounds` into per-shard lists of disjoint
/// `&mut [f32]` pieces (tensor-order within each shard).
pub fn split_mut<'a>(pv: &'a mut ParamVec, bounds: &[usize]) -> Vec<Vec<&'a mut [f32]>> {
    let s = bounds.len() - 1;
    let mut shards: Vec<Vec<&'a mut [f32]>> = (0..s).map(|_| Vec::new()).collect();
    let mut off = 0usize;
    for t in &mut pv.tensors {
        let tlen = t.len();
        let mut rest: &'a mut [f32] = t.data_mut();
        for (i, shard) in shards.iter_mut().enumerate() {
            let lo = bounds[i].max(off);
            let hi = bounds[i + 1].min(off + tlen);
            if hi <= lo {
                continue;
            }
            let (piece, tail) = std::mem::take(&mut rest).split_at_mut(hi - lo);
            shard.push(piece);
            rest = tail;
        }
        off += tlen;
    }
    shards
}

/// Shared-reference twin of [`split_mut`]: shard `i` is
/// [`pieces_in`]`(pv, bounds[i], bounds[i+1])`.
pub fn split_ref<'a>(pv: &'a ParamVec, bounds: &[usize]) -> Vec<Vec<&'a [f32]>> {
    bounds
        .windows(2)
        .map(|w| pieces_in(pv, w[0], w[1]))
        .collect()
}

/// The pieces of `pv`'s flat range `[lo, hi)` (one shard's view).
pub fn pieces_in<'a>(pv: &'a ParamVec, lo: usize, hi: usize) -> Vec<&'a [f32]> {
    let mut out = Vec::new();
    let mut off = 0usize;
    for t in &pv.tensors {
        let data = t.data();
        let tlen = data.len();
        let a = lo.max(off);
        let b = hi.min(off + tlen);
        if b > a {
            out.push(&data[a - off..b - off]);
        }
        off += tlen;
    }
    out
}

// ------------------------------------------- scoped parallel runners
//
// Each runner spawns `s - 1` scoped workers and runs the first shard on
// the calling thread.  Piece lists of same-shape ParamVecs split at the
// same bounds align index-by-index, so zipping pieces pairs the same
// flat ranges.  Workers re-apply the *caller's* resolved kernel backend
// (the override is thread-local), so a forced-backend section — a
// bit-equality test leg, a bench — runs that backend on every shard,
// not just the calling thread's.

/// Apply `f` to every shard piece of `out`.
pub(crate) fn run1<F>(out: &mut ParamVec, s: usize, f: F)
where
    F: Fn(&mut [f32]) + Sync,
{
    let backend = kernels::active_backend();
    let bounds = shard_bounds(out.num_elements(), s);
    let shards = split_mut(out, &bounds);
    std::thread::scope(|scope| {
        let f = &f;
        let mut iter = shards.into_iter();
        let first = iter.next();
        for pieces in iter {
            scope.spawn(move || {
                kernels::with_backend(backend, || {
                    for p in pieces {
                        f(p);
                    }
                })
            });
        }
        if let Some(pieces) = first {
            for p in pieces {
                f(p);
            }
        }
    });
}

/// Apply `f` to aligned (dst, src) shard pieces.
pub(crate) fn run2<F>(dst: &mut ParamVec, src: &ParamVec, s: usize, f: F)
where
    F: Fn(&mut [f32], &[f32]) + Sync,
{
    let backend = kernels::active_backend();
    let bounds = shard_bounds(dst.num_elements(), s);
    let d = split_mut(dst, &bounds);
    let r = split_ref(src, &bounds);
    std::thread::scope(|scope| {
        let f = &f;
        let mut iter = d.into_iter().zip(r);
        let first = iter.next();
        for (dp, rp) in iter {
            scope.spawn(move || {
                kernels::with_backend(backend, || {
                    for (a, b) in dp.into_iter().zip(rp) {
                        f(a, b);
                    }
                })
            });
        }
        if let Some((dp, rp)) = first {
            for (a, b) in dp.into_iter().zip(rp) {
                f(a, b);
            }
        }
    });
}

/// Apply `f` to aligned (out, a, b) shard pieces.
pub(crate) fn run3<F>(out: &mut ParamVec, a: &ParamVec, b: &ParamVec, s: usize, f: F)
where
    F: Fn(&mut [f32], &[f32], &[f32]) + Sync,
{
    let backend = kernels::active_backend();
    let bounds = shard_bounds(out.num_elements(), s);
    let o = split_mut(out, &bounds);
    let av = split_ref(a, &bounds);
    let bv = split_ref(b, &bounds);
    std::thread::scope(|scope| {
        let f = &f;
        let mut iter = o.into_iter().zip(av).zip(bv);
        let first = iter.next();
        for ((op, ap), bp) in iter {
            scope.spawn(move || {
                kernels::with_backend(backend, || {
                    for ((z, x), y) in op.into_iter().zip(ap).zip(bp) {
                        f(z, x, y);
                    }
                })
            });
        }
        if let Some(((op, ap), bp)) = first {
            for ((z, x), y) in op.into_iter().zip(ap).zip(bp) {
                f(z, x, y);
            }
        }
    });
}

/// One fused SyncSGD round (Eq. 1) over `s` shards: per shard, zero the
/// scratch, accumulate `w·gᵢ` in push order, then apply
/// `params -= eta·scratch`.  Per-element this is the exact sequence of
/// the sequential `fill` / `axpy`×K / `axpy` round, so the result is
/// bit-identical for every shard count.
pub fn par_sync_sgd(
    params: &mut ParamVec,
    scratch: &mut ParamVec,
    grads: &[ParamVec],
    w: f32,
    eta: f32,
    s: usize,
) {
    let n = params.num_elements();
    assert!(
        grads.iter().all(|g| g.num_elements() == n),
        "gradient/param element-count mismatch"
    );
    let backend = kernels::active_backend();
    let bounds = shard_bounds(n, s);
    let p = split_mut(params, &bounds);
    let a = split_mut(scratch, &bounds);
    std::thread::scope(|scope| {
        let bounds = &bounds;
        let mut iter = p.into_iter().zip(a).enumerate();
        let first = iter.next();
        for (j, (pp, ap)) in iter {
            let gj: Vec<Vec<&[f32]>> = grads
                .iter()
                .map(|g| pieces_in(g, bounds[j], bounds[j + 1]))
                .collect();
            scope.spawn(move || {
                kernels::with_backend(backend, || sync_shard(pp, ap, &gj, w, eta))
            });
        }
        if let Some((j, (pp, ap))) = first {
            let gj: Vec<Vec<&[f32]>> = grads
                .iter()
                .map(|g| pieces_in(g, bounds[j], bounds[j + 1]))
                .collect();
            sync_shard(pp, ap, &gj, w, eta);
        }
    });
}

fn sync_shard(
    mut pp: Vec<&mut [f32]>,
    mut ap: Vec<&mut [f32]>,
    gj: &[Vec<&[f32]>],
    w: f32,
    eta: f32,
) {
    for a in ap.iter_mut() {
        kernels::fill(a, 0.0);
    }
    for g in gj {
        for (a, gp) in ap.iter_mut().zip(g) {
            kernels::axpy_in_place(a, w, gp);
        }
    }
    for (p, a) in pp.iter_mut().zip(ap.iter()) {
        kernels::axpy_in_place(p, -eta, a);
    }
}

/// Parallel element→byte codec pass: split `src` at element bounds and
/// `dst` at `bpe·bounds`, then run `f` (e.g. the dispatched f16 encode)
/// on aligned range pairs.  `dst.len()` must equal `bpe * src.len()`.
pub(crate) fn par_bytes<F>(dst: &mut [u8], src: &[f32], bpe: usize, s: usize, f: F)
where
    F: Fn(&[f32], &mut [u8]) + Sync,
{
    debug_assert_eq!(dst.len(), bpe * src.len());
    let backend = kernels::active_backend();
    let bounds = shard_bounds(src.len(), s);
    let mut rest_d = dst;
    let mut rest_s = src;
    std::thread::scope(|scope| {
        let f = &f;
        for j in 1..bounds.len() {
            let take = bounds[j] - bounds[j - 1];
            let (sd, td) = std::mem::take(&mut rest_d).split_at_mut(take * bpe);
            let (ss, ts) = rest_s.split_at(take);
            rest_d = td;
            rest_s = ts;
            if j == bounds.len() - 1 {
                f(ss, sd); // last shard runs on the calling thread
            } else {
                scope.spawn(move || kernels::with_backend(backend, || f(ss, sd)));
            }
        }
    });
}

/// Parallel byte→element codec pass (e.g. the dispatched f16 decode).
/// `src.len()` must equal `bpe * dst.len()`.
pub(crate) fn par_from_bytes<F>(dst: &mut [f32], src: &[u8], bpe: usize, s: usize, f: F)
where
    F: Fn(&[u8], &mut [f32]) + Sync,
{
    debug_assert_eq!(src.len(), bpe * dst.len());
    let backend = kernels::active_backend();
    let bounds = shard_bounds(dst.len(), s);
    let mut rest_d = dst;
    let mut rest_s = src;
    std::thread::scope(|scope| {
        let f = &f;
        for j in 1..bounds.len() {
            let take = bounds[j] - bounds[j - 1];
            let (sd, td) = std::mem::take(&mut rest_d).split_at_mut(take);
            let (ss, ts) = rest_s.split_at(take * bpe);
            rest_d = td;
            rest_s = ts;
            if j == bounds.len() - 1 {
                f(ss, sd);
            } else {
                scope.spawn(move || kernels::with_backend(backend, || f(ss, sd)));
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::super::Tensor;
    use super::*;

    fn pv(lens: &[usize]) -> ParamVec {
        let mut c = 0.0f32;
        ParamVec {
            tensors: lens
                .iter()
                .map(|&n| {
                    Tensor::new(
                        vec![n],
                        (0..n)
                            .map(|_| {
                                c += 1.0;
                                c
                            })
                            .collect(),
                    )
                })
                .collect(),
        }
    }

    #[test]
    fn bounds_cover_exactly_once() {
        for n in [0usize, 1, 7, 64, 65, 1000] {
            for s in 1..=9 {
                let b = shard_bounds(n, s);
                assert_eq!(b.len(), s + 1);
                assert_eq!(b[0], 0);
                assert_eq!(*b.last().unwrap(), n);
                assert!(b.windows(2).all(|w| w[0] <= w[1]));
                // Even split: sizes differ by at most one.
                let sizes: Vec<usize> = b.windows(2).map(|w| w[1] - w[0]).collect();
                let (mn, mx) = (
                    *sizes.iter().min().unwrap(),
                    *sizes.iter().max().unwrap(),
                );
                assert!(mx - mn <= 1, "n={n} s={s} {sizes:?}");
            }
        }
    }

    #[test]
    fn split_mut_partitions_every_element_in_order() {
        // Tensor lens include empty and single-element tensors; shard
        // boundaries straddle tensors.
        for lens in [&[0usize, 5, 1, 0, 9, 3][..], &[17][..], &[0, 0][..]] {
            let total: usize = lens.iter().sum();
            for s in 1..=5 {
                let mut p = pv(lens);
                let bounds = shard_bounds(total, s);
                let shards = split_mut(&mut p, &bounds);
                let flat: Vec<f32> = shards
                    .iter()
                    .flat_map(|pieces| pieces.iter().flat_map(|pc| pc.iter().copied()))
                    .collect();
                let want: Vec<f32> = (1..=total).map(|i| i as f32).collect();
                assert_eq!(flat, want, "lens={lens:?} s={s}");
                // Shard i holds exactly bounds[i+1]-bounds[i] elements.
                for (i, pieces) in shards.iter().enumerate() {
                    let got: usize = pieces.iter().map(|pc| pc.len()).sum();
                    assert_eq!(got, bounds[i + 1] - bounds[i]);
                }
            }
        }
    }

    #[test]
    fn split_ref_and_pieces_in_agree_with_split_mut() {
        let lens = &[3usize, 0, 11, 6];
        let total: usize = lens.iter().sum();
        let p = pv(lens);
        let bounds = shard_bounds(total, 3);
        let refs = split_ref(&p, &bounds);
        for (i, pieces) in refs.iter().enumerate() {
            let direct = pieces_in(&p, bounds[i], bounds[i + 1]);
            let a: Vec<f32> = pieces.iter().flat_map(|pc| pc.iter().copied()).collect();
            let b: Vec<f32> = direct.iter().flat_map(|pc| pc.iter().copied()).collect();
            assert_eq!(a, b);
        }
    }

    #[test]
    fn with_shards_overrides_and_restores() {
        let base = shard_count(10);
        with_shards(5, || {
            assert_eq!(shard_count(10), 5);
            assert_eq!(shard_count(0), 5);
        });
        assert_eq!(shard_count(10), base);
        // Auto policy: small buffers stay inline.
        if std::env::var_os("HERMES_SHARDS").is_none() {
            assert_eq!(shard_count(SHARD_MIN_ELEMS), 1);
            assert!(shard_count(16 * SHARD_MIN_ELEMS) >= 1);
        }
    }

    #[test]
    fn par_runners_match_inline_for_any_shard_count() {
        let lens = &[0usize, 13, 1, 300, 7];
        let total: usize = lens.iter().sum();
        let a = pv(lens);
        let b = {
            let mut b = pv(lens);
            b.scale_in_place(0.5);
            b
        };
        let mut want = pv(lens);
        for (w, (x, y)) in want
            .tensors
            .iter_mut()
            .flat_map(|t| t.data_mut().iter_mut())
            .zip(
                a.tensors
                    .iter()
                    .flat_map(|t| t.data())
                    .zip(b.tensors.iter().flat_map(|t| t.data())),
            )
        {
            *w = 0.3 * x + 0.7 * y;
        }
        for s in 1..=6 {
            let mut out = pv(lens);
            run3(&mut out, &a, &b, s, |z, x, y| {
                kernels::weighted_sum(z, x, 0.3, y, 0.7)
            });
            assert_eq!(out, want, "s={s} total={total}");
        }
    }
}
