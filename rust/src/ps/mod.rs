//! The parameter server: global model state and the three aggregation
//! algebras the paper compares —
//!
//! * **SyncSGD** (Eq. 1, BSP): average the round's gradients.
//! * **AsyncSGD** (Eq. 2, ASP/SSP): apply each push immediately.
//! * **Loss-based SGD** (Alg. 2, Hermes): weight the stored cumulative
//!   gradient ς and the incoming G by the reciprocals of their test
//!   losses, so gradients that *generalize* pull harder (Eqs. 5–6).

use anyhow::Result;

use crate::data::Probe;
use crate::runtime::{EvalOut, ModelRuntime};
use crate::tensor::{shards, ParamVec};
use crate::wire::{decode_param_vec, encode_param_vec, WireError};

/// Magic prefix of a PS snapshot.
const SNAP_MAGIC: [u8; 4] = *b"PSNP";

/// Current snapshot layout version — bump on any format change;
/// [`PsState::decode_snapshot`] rejects every other version.
pub const SNAPSHOT_VERSION: u32 = 1;

/// Global model state at the PS.
///
/// All three aggregation algebras run over two private scratch buffers
/// sized on first use, so steady-state aggregation performs zero heap
/// allocations (DESIGN.md §8; asserted by `tests/alloc_hotpath.rs`).
#[derive(Debug, Clone)]
pub struct PsState {
    /// The frozen baseline w₀ every cumulative gradient refers to.
    pub w0: ParamVec,
    /// Current global parameters.
    pub params: ParamVec,
    /// ς — the stored cumulative global gradient (Alg. 2).
    pub sigma: Option<ParamVec>,
    /// Test loss of the current global model (L in Alg. 2).
    pub loss: f32,
    /// Latest global test accuracy (bookkeeping for convergence).
    pub accuracy: f64,
    pub eta: f32,
    pub version: u64,
    /// Aggregations performed.
    pub updates: u64,
    /// Scratch: gradient mean (Eq. 1) / w_temp (Alg. 2).
    scratch_a: ParamVec,
    /// Scratch: the ς-merge target swapped into `sigma` (Alg. 2).
    scratch_b: ParamVec,
}

impl PsState {
    pub fn new(w0: ParamVec, eta: f32) -> Self {
        PsState {
            params: w0.clone(),
            w0,
            sigma: None,
            loss: f32::INFINITY,
            accuracy: 0.0,
            eta,
            version: 0,
            updates: 0,
            scratch_a: ParamVec::default(),
            scratch_b: ParamVec::default(),
        }
    }

    /// Evaluate the global model on the PS probe set, refreshing the
    /// stored loss/accuracy.
    pub fn eval_global(
        &mut self,
        rt: &mut dyn ModelRuntime,
        probe: &Probe,
    ) -> Result<EvalOut> {
        let out = rt.eval_step(&self.params, &probe.x, &probe.y)?;
        self.loss = out.loss;
        self.accuracy = probe.accuracy(out.correct);
        Ok(out)
    }

    /// **SyncSGD** (Eq. 1): one superstep's aggregation.  `grads` are
    /// the per-worker local gradient sums of this round (direction of
    /// descent, i.e. w ← w − η·mean g).  The mean accumulates in a
    /// reused scratch buffer — no per-round allocation — and at model
    /// sizes past the shard threshold the whole round (zero, K
    /// accumulates, apply) runs **fused over parallel shards** in one
    /// scoped-thread region: elementwise ops over disjoint flat ranges,
    /// so the result is bit-identical for any shard count
    /// (DESIGN.md §12; property-tested across all six drivers).
    pub fn sync_sgd(&mut self, grads: &[ParamVec]) {
        assert!(!grads.is_empty());
        self.scratch_a.resize_like(&self.params);
        let w = 1.0 / grads.len() as f32;
        let s = shards::shard_count(self.params.num_elements());
        if s > 1 {
            shards::par_sync_sgd(
                &mut self.params,
                &mut self.scratch_a,
                grads,
                w,
                self.eta,
                s,
            );
        } else {
            self.scratch_a.fill(0.0);
            for g in grads {
                self.scratch_a.axpy(w, g);
            }
            self.params.axpy(-self.eta, &self.scratch_a);
        }
        self.bump();
    }

    /// **RobustAgg** — coordinate-wise trimmed mean over a round's
    /// surviving gradients (DESIGN.md §15).  `trim_fraction` of the
    /// per-coordinate samples is discarded from *each* tail before
    /// averaging, so up to that fraction of blown-up or sign-flipped
    /// deltas cannot move the mean arbitrarily.  Deliberately
    /// **scalar-ordered** like every reduction (DESIGN.md §12): the
    /// per-coordinate sort + sum runs in one fixed order, so the
    /// result is bit-identical across SIMD backends and shard counts.
    /// Never called on the defenses-off path, which keeps those runs
    /// byte-identical to [`PsState::sync_sgd`] rounds.
    pub fn robust_sync_sgd(&mut self, grads: &[ParamVec], trim_fraction: f64) {
        assert!(!grads.is_empty());
        let k = grads.len();
        let trim_k =
            (((k as f64) * trim_fraction).floor() as usize).min((k - 1) / 2);
        self.scratch_a.resize_like(&self.params);
        let w = 1.0 / (k - 2 * trim_k) as f32;
        let mut vals = vec![0.0f32; k];
        for (ti, out_t) in self.scratch_a.tensors.iter_mut().enumerate() {
            let out = out_t.data_mut();
            for (i, slot) in out.iter_mut().enumerate() {
                for (v, g) in vals.iter_mut().zip(grads) {
                    *v = g.tensors[ti].data()[i];
                }
                vals.sort_unstable_by(|a, b| a.total_cmp(b));
                let mut s = 0.0f32;
                for &v in &vals[trim_k..k - trim_k] {
                    s += v;
                }
                *slot = s * w;
            }
        }
        self.params.axpy(-self.eta, &self.scratch_a);
        self.bump();
    }

    /// **AsyncSGD** (Eq. 2): apply one worker's gradient immediately.
    pub fn async_sgd(&mut self, grad: &ParamVec) {
        self.params.axpy(-self.eta, grad);
        self.bump();
    }

    /// **Loss-based SGD** (Alg. 2).  `g` is the worker's cumulative
    /// gradient from w₀; `t_w` its test loss.  Needs the runtime to
    /// evaluate the temporary model w_temp = w₀ − η·G (and the merged
    /// global).  Returns the (L_temp, L) pair for metrics/Fig. 13.
    /// Every `copy_from`/`axpy`/`weighted_sum_into` below is
    /// SIMD-dispatched and auto-sharded by the tensor layer
    /// (DESIGN.md §12) — the per-push algebra scales with cores at
    /// large model sizes while staying bit-identical.
    pub fn loss_based_sgd(
        &mut self,
        g: &ParamVec,
        _t_w: f32,
        rt: &mut dyn ModelRuntime,
        probe: &Probe,
    ) -> Result<(f32, f32)> {
        if self.sigma.is_none() {
            // Initial step: ς ← G; w₁ = w₀ − η·ς; L = eval(w₁).
            self.sigma = Some(g.clone());
            self.params.copy_from(&self.w0);
            self.params.axpy(-self.eta, g);
            let out = self.eval_global(rt, probe)?;
            self.bump();
            Ok((out.loss, out.loss))
        } else {
            // w_temp = w₀ − η·G, L_temp = eval(w_temp) — built in the
            // reused scratch instead of cloning w₀ per push.
            self.scratch_a.copy_from(&self.w0);
            self.scratch_a.axpy(-self.eta, g);
            let tmp = rt.eval_step(&self.scratch_a, &probe.x, &probe.y)?;
            let l_temp = tmp.loss.max(1e-6);
            let l_glob = self.loss.max(1e-6);
            // W₁ = 1/L (global), W₂ = 1/L_temp (worker) — Alg. 2.
            let w1 = 1.0 / l_glob;
            let w2 = 1.0 / l_temp;
            let denom = w1 + w2;
            ParamVec::weighted_sum_into(
                self.sigma.as_ref().unwrap(),
                w1 / denom,
                g,
                w2 / denom,
                &mut self.scratch_b,
            );
            // The merged ς swaps in; the old buffer becomes next
            // push's merge target.
            std::mem::swap(self.sigma.as_mut().unwrap(), &mut self.scratch_b);
            self.params.copy_from(&self.w0);
            self.params.axpy(-self.eta, self.sigma.as_ref().unwrap());
            let out = self.eval_global(rt, probe)?;
            self.bump();
            Ok((l_temp, out.loss))
        }
    }

    fn bump(&mut self) {
        self.version += 1;
        self.updates += 1;
    }

    // ------------------------------------------- checkpoint / restore

    /// Serialize the complete PS state (fp32-lossless, through the wire
    /// tensor codec) — the checkpoint half of crash recovery for the
    /// elastic subsystem (DESIGN.md §10).
    pub fn encode_snapshot(&self) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(&SNAP_MAGIC);
        buf.extend_from_slice(&SNAPSHOT_VERSION.to_le_bytes());
        buf.extend_from_slice(&self.eta.to_le_bytes());
        buf.extend_from_slice(&self.version.to_le_bytes());
        buf.extend_from_slice(&self.updates.to_le_bytes());
        buf.extend_from_slice(&self.loss.to_le_bytes());
        buf.extend_from_slice(&self.accuracy.to_le_bytes());
        encode_param_vec(&self.w0, false, &mut buf);
        encode_param_vec(&self.params, false, &mut buf);
        buf.push(self.sigma.is_some() as u8);
        if let Some(sigma) = &self.sigma {
            encode_param_vec(sigma, false, &mut buf);
        }
        buf
    }

    /// Restore a PS from [`PsState::encode_snapshot`] bytes.  Unknown
    /// versions, truncation and trailing garbage are all rejected — a
    /// restored PS continues bit-identically to the one that
    /// checkpointed (tested below).
    pub fn decode_snapshot(buf: &[u8]) -> Result<PsState, WireError> {
        fn take<'a>(
            buf: &'a [u8],
            pos: &mut usize,
            n: usize,
        ) -> Result<&'a [u8], WireError> {
            if buf.len() - *pos < n {
                return Err(WireError::Truncated { at: *pos, wanted: n });
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        }
        let mut pos = 0usize;
        let b = take(buf, &mut pos, 4)?;
        if b != &SNAP_MAGIC[..] {
            return Err(WireError::Malformed("snapshot magic"));
        }
        let b = take(buf, &mut pos, 4)?;
        let snap_version = u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        if snap_version != SNAPSHOT_VERSION {
            return Err(WireError::Malformed("unsupported snapshot version"));
        }
        let b = take(buf, &mut pos, 4)?;
        let eta = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        let b = take(buf, &mut pos, 8)?;
        let version = u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]);
        let b = take(buf, &mut pos, 8)?;
        let updates = u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]);
        let b = take(buf, &mut pos, 4)?;
        let loss = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        let b = take(buf, &mut pos, 8)?;
        let accuracy =
            f64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]);
        let (w0, used) = decode_param_vec(&buf[pos..])?;
        pos += used;
        let (params, used) = decode_param_vec(&buf[pos..])?;
        pos += used;
        let has_sigma = take(buf, &mut pos, 1)?[0] != 0;
        let sigma = if has_sigma {
            let (s, used) = decode_param_vec(&buf[pos..])?;
            pos += used;
            Some(s)
        } else {
            None
        };
        if pos != buf.len() {
            return Err(WireError::Malformed("trailing bytes"));
        }
        Ok(PsState {
            w0,
            params,
            sigma,
            loss,
            accuracy,
            eta,
            version,
            updates,
            scratch_a: ParamVec::default(),
            scratch_b: ParamVec::default(),
        })
    }
}

/// The identity lift onto the tier surface (ISSUE 10): the trait
/// methods *are* `sync_sgd` / `async_sgd` / the `PSNP` snapshot codec,
/// so an in-process tier is bit-identical to the pre-trait parameter
/// server by construction.
impl crate::aggregator::Aggregator for PsState {
    fn apply_round(&mut self, grads: &[ParamVec]) {
        self.sync_sgd(grads);
    }

    fn apply_async(&mut self, grad: &ParamVec) {
        self.async_sgd(grad);
    }

    fn params(&self) -> &ParamVec {
        &self.params
    }

    fn version(&self) -> u64 {
        self.version
    }

    fn updates(&self) -> u64 {
        self.updates
    }

    fn snapshot(&self) -> Vec<u8> {
        self.encode_snapshot()
    }

    fn resync(&mut self, snap: &[u8]) -> Result<(), WireError> {
        *self = PsState::decode_snapshot(snap)?;
        Ok(())
    }
}

/// How many accepted update norms the guard remembers; the median of
/// this ring is the reference scale for the relative-norm bound.
const GUARD_WINDOW: usize = 32;

/// PS-side admission control for incoming deltas (DESIGN.md §15).
///
/// Two checks, both deterministic and scalar-ordered:
///
/// 1. **Finite check** — any NaN/Inf coordinate quarantines the update
///    outright (a single poisoned coordinate would otherwise infect
///    every global parameter through the mean).
/// 2. **Relative-norm bound** — the update's L2 norm may not exceed
///    `norm_bound ×` the median of the last [`GUARD_WINDOW`] *accepted*
///    norms.  Using accepted history only means a blow-up can't widen
///    its own admission window; using the median (not the mean) means
///    one borderline-large accepted update barely moves the reference.
///
/// With no history yet (or an all-zero history) only the finite check
/// applies — the first pushes of a run define the scale.
#[derive(Debug, Clone)]
pub struct UpdateGuard {
    norm_bound: f64,
    recent: Vec<f64>,
    next: usize,
    /// Updates admitted to aggregation.
    pub accepted: u64,
    /// Updates rejected (quarantined) by either check.
    pub quarantined: u64,
}

impl UpdateGuard {
    pub fn new(norm_bound: f64) -> Self {
        UpdateGuard {
            norm_bound,
            recent: Vec::with_capacity(GUARD_WINDOW),
            next: 0,
            accepted: 0,
            quarantined: 0,
        }
    }

    /// Median of the accepted-norm ring (0.0 while empty).
    fn reference_norm(&self) -> f64 {
        if self.recent.is_empty() {
            return 0.0;
        }
        let mut sorted = self.recent.clone();
        sorted.sort_unstable_by(|a, b| a.total_cmp(b));
        let mid = sorted.len() / 2;
        if sorted.len() % 2 == 1 {
            sorted[mid]
        } else {
            0.5 * (sorted[mid - 1] + sorted[mid])
        }
    }

    /// Admit or quarantine one incoming update.  Returns `true` when
    /// the update may be aggregated; `false` quarantines it (the
    /// caller drops the delta and counts it).
    pub fn admit(&mut self, g: &ParamVec) -> bool {
        let finite =
            g.tensors.iter().all(|t| t.data().iter().all(|x| x.is_finite()));
        if !finite {
            self.quarantined += 1;
            return false;
        }
        let n = g.l2_norm();
        let reference = self.reference_norm();
        if reference > 0.0 && n > self.norm_bound * reference {
            self.quarantined += 1;
            return false;
        }
        if self.recent.len() < GUARD_WINDOW {
            self.recent.push(n);
        } else {
            self.recent[self.next] = n;
            self.next = (self.next + 1) % GUARD_WINDOW;
        }
        self.accepted += 1;
        true
    }

    /// The accepted-norm ring and its write cursor — live-mode
    /// checkpoints persist these so a restored coordinator's guard
    /// makes the same admission decisions as the one that crashed.
    pub fn history(&self) -> (&[f64], usize) {
        (&self.recent, self.next)
    }

    /// Restore the ring persisted by [`UpdateGuard::history`].
    /// Oversized or inconsistent inputs are clamped, never trusted.
    pub fn restore_history(&mut self, recent: Vec<f64>, next: usize) {
        self.recent = recent;
        self.recent.truncate(GUARD_WINDOW);
        // A ring that never wrapped keeps its cursor at 0 (matching a
        // guard that grew the same history without a restart).
        self.next = if self.recent.len() < GUARD_WINDOW {
            0
        } else {
            next % GUARD_WINDOW
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DataKind, Dataset, Probe};
    use crate::runtime::{init_params, MockRuntime, ModelRuntime};
    use crate::tensor::Tensor;

    fn pv(vals: &[f32]) -> ParamVec {
        ParamVec { tensors: vec![Tensor::new(vec![vals.len()], vals.to_vec())] }
    }

    #[test]
    fn sync_sgd_averages_gradients() {
        let mut ps = PsState::new(pv(&[1.0, 1.0]), 0.5);
        ps.sync_sgd(&[pv(&[1.0, 0.0]), pv(&[0.0, 1.0])]);
        // mean g = [0.5, 0.5]; w = 1 − 0.5·0.5 = 0.75.
        assert_eq!(ps.params, pv(&[0.75, 0.75]));
        assert_eq!(ps.version, 1);
    }

    #[test]
    fn async_sgd_applies_each_push() {
        let mut ps = PsState::new(pv(&[0.0]), 0.1);
        ps.async_sgd(&pv(&[1.0]));
        ps.async_sgd(&pv(&[1.0]));
        assert!((ps.params.tensors[0].data()[0] - (-0.2)).abs() < 1e-6);
        assert_eq!(ps.updates, 2);
    }

    fn probe_for_mock() -> (MockRuntime, Probe) {
        let rt = MockRuntime::new();
        let ds = Dataset::synth(DataKind::MockSet, 600, 11);
        let (_, test) = ds.split(0.7, 11);
        let probe = Probe::build(&ds, &test, rt.meta().eval_batch, 11);
        (rt, probe)
    }

    #[test]
    fn loss_based_first_push_adopts_g() {
        let (mut rt, probe) = probe_for_mock();
        let w0 = init_params(rt.meta(), 1);
        let mut ps = PsState::new(w0.clone(), 0.1);
        let g = {
            let mut g = ParamVec::zeros_like(&w0);
            g.tensors[0].data_mut()[0] = 2.0;
            g
        };
        ps.loss_based_sgd(&g, 1.0, &mut rt, &probe).unwrap();
        assert!(ps.sigma.is_some());
        // w = w0 − η·G exactly.
        let expect = w0.tensors[0].data()[0] - 0.1 * 2.0;
        assert!((ps.params.tensors[0].data()[0] - expect).abs() < 1e-6);
        assert!(ps.loss.is_finite());
    }

    #[test]
    fn loss_based_merge_is_convex_in_sigma_and_g() {
        let (mut rt, probe) = probe_for_mock();
        let w0 = init_params(rt.meta(), 2);
        let mut ps = PsState::new(w0.clone(), 0.05);
        let mut g1 = ParamVec::zeros_like(&w0);
        g1.tensors[0].data_mut()[0] = 1.0;
        let mut g2 = ParamVec::zeros_like(&w0);
        g2.tensors[0].data_mut()[0] = 3.0;
        ps.loss_based_sgd(&g1, 1.0, &mut rt, &probe).unwrap();
        ps.loss_based_sgd(&g2, 1.0, &mut rt, &probe).unwrap();
        // ς must lie strictly between g1 and g2 component-wise (convex
        // combination with positive weights).
        let s = ps.sigma.as_ref().unwrap().tensors[0].data()[0];
        assert!(s > 1.0 && s < 3.0, "sigma {s}");
        // params = w0 − η·ς (PS invariant, DESIGN.md §7).
        let expect = w0.tensors[0].data()[0] - 0.05 * s;
        assert!((ps.params.tensors[0].data()[0] - expect).abs() < 1e-6);
    }

    #[test]
    fn loss_based_equal_losses_average_evenly() {
        // With L == L_temp the merge is a plain average — check via a
        // synthetic runtime whose eval loss is constant.
        struct ConstLoss(MockRuntime);
        impl ModelRuntime for ConstLoss {
            fn meta(&self) -> &crate::runtime::ModelMeta {
                self.0.meta()
            }
            fn train_step(
                &mut self,
                p: &ParamVec,
                m: &ParamVec,
                x: &[f32],
                y: &[i32],
                mbs: usize,
                lr: f32,
                mu: f32,
            ) -> Result<crate::runtime::TrainOut> {
                self.0.train_step(p, m, x, y, mbs, lr, mu)
            }
            fn eval_step(
                &mut self,
                _p: &ParamVec,
                _x: &[f32],
                _y: &[i32],
            ) -> Result<crate::runtime::EvalOut> {
                Ok(crate::runtime::EvalOut { loss: 0.7, correct: 0.0 })
            }
            fn exec_count(&self) -> u64 {
                0
            }
        }
        let (rt0, probe) = probe_for_mock();
        let mut rt = ConstLoss(rt0);
        let w0 = init_params(rt.meta(), 3);
        let mut ps = PsState::new(w0.clone(), 0.1);
        let mut g1 = ParamVec::zeros_like(&w0);
        g1.tensors[0].data_mut()[0] = 2.0;
        let mut g2 = ParamVec::zeros_like(&w0);
        g2.tensors[0].data_mut()[0] = 4.0;
        ps.loss_based_sgd(&g1, 0.7, &mut rt, &probe).unwrap();
        ps.loss_based_sgd(&g2, 0.7, &mut rt, &probe).unwrap();
        let s = ps.sigma.as_ref().unwrap().tensors[0].data()[0];
        assert!((s - 3.0).abs() < 1e-6, "sigma {s}");
    }

    #[test]
    fn snapshot_roundtrips_and_restored_ps_continues_bit_identically() {
        let (mut rt, probe) = probe_for_mock();
        let w0 = init_params(rt.meta(), 5);
        let mut ps = PsState::new(w0.clone(), 0.1);
        let mut g1 = ParamVec::zeros_like(&w0);
        g1.tensors[0].data_mut()[0] = 1.5;
        let mut g2 = ParamVec::zeros_like(&w0);
        g2.tensors[0].data_mut()[1] = -0.75;
        ps.loss_based_sgd(&g1, 1.0, &mut rt, &probe).unwrap();
        ps.loss_based_sgd(&g2, 0.9, &mut rt, &probe).unwrap();

        let snap = ps.encode_snapshot();
        let mut restored = PsState::decode_snapshot(&snap).unwrap();
        assert_eq!(restored.w0, ps.w0);
        assert_eq!(restored.params, ps.params);
        assert_eq!(restored.sigma, ps.sigma);
        assert_eq!(restored.version, ps.version);
        assert_eq!(restored.updates, ps.updates);
        assert_eq!(restored.loss.to_bits(), ps.loss.to_bits());
        assert_eq!(restored.accuracy.to_bits(), ps.accuracy.to_bits());
        assert_eq!(restored.eta.to_bits(), ps.eta.to_bits());

        // The restored PS must continue exactly like the original.
        let mut g3 = ParamVec::zeros_like(&w0);
        g3.tensors[0].data_mut()[2] = 0.25;
        let mut rt2 = probe_for_mock().0;
        ps.loss_based_sgd(&g3, 0.8, &mut rt, &probe).unwrap();
        restored.loss_based_sgd(&g3, 0.8, &mut rt2, &probe).unwrap();
        assert_eq!(restored.params, ps.params);
        assert_eq!(restored.sigma, ps.sigma);
        assert_eq!(restored.loss.to_bits(), ps.loss.to_bits());

        // Pre-sigma snapshots (fresh PS) roundtrip too.
        let fresh = PsState::new(w0, 0.05);
        let back = PsState::decode_snapshot(&fresh.encode_snapshot()).unwrap();
        assert!(back.sigma.is_none());
        assert_eq!(back.params, fresh.params);
    }

    #[test]
    fn snapshot_rejects_corruption_truncation_and_wrong_version() {
        let ps = PsState::new(pv(&[1.0, 2.0, 3.0]), 0.1);
        let snap = ps.encode_snapshot();
        // Every strict prefix is rejected.
        for cut in 0..snap.len() {
            assert!(PsState::decode_snapshot(&snap[..cut]).is_err(), "cut {cut}");
        }
        // Trailing garbage is rejected.
        let mut padded = snap.clone();
        padded.push(0);
        assert!(PsState::decode_snapshot(&padded).is_err());
        // Wrong magic.
        let mut bad = snap.clone();
        bad[0] ^= 0xFF;
        assert!(PsState::decode_snapshot(&bad).is_err());
        // Unsupported version.
        let mut v2 = snap;
        v2[4] = 99;
        assert!(PsState::decode_snapshot(&v2).is_err());
    }

    #[test]
    fn robust_trimmed_mean_discards_outliers() {
        // k = 4, trim 0.25 ⇒ one sample trimmed per tail; the blown-up
        // delta and the zero delta both fall away, leaving mean(1, 1).
        let mut ps = PsState::new(pv(&[1.0, 1.0]), 0.5);
        ps.robust_sync_sgd(
            &[
                pv(&[1.0, 1.0]),
                pv(&[1.0, 1.0]),
                pv(&[0.0, 0.0]),
                pv(&[1.0e6, -1.0e6]),
            ],
            0.25,
        );
        // w = 1 − 0.5·1 = 0.5 on both coordinates, untouched by the 1e6.
        assert_eq!(ps.params, pv(&[0.5, 0.5]));
        assert_eq!(ps.version, 1);
    }

    #[test]
    fn robust_trimmed_mean_with_zero_trim_matches_plain_mean() {
        let mut a = PsState::new(pv(&[2.0, -1.0]), 0.25);
        a.robust_sync_sgd(&[pv(&[1.0, 3.0]), pv(&[3.0, 1.0])], 0.0);
        // mean g = [2, 2]; w = [2 − 0.25·2, −1 − 0.25·2] = [1.5, −1.5].
        assert_eq!(a.params, pv(&[1.5, -1.5]));
    }

    #[test]
    fn robust_trimmed_mean_caps_trim_to_keep_one_sample() {
        // trim 0.49 of k = 2 would trim zero per tail; trim 0.9 is
        // clamped so at least one sample survives.
        let mut ps = PsState::new(pv(&[0.0]), 1.0);
        ps.robust_sync_sgd(&[pv(&[2.0]), pv(&[4.0])], 0.9);
        // trim_k = min(floor(2·0.9), (2−1)/2) = 0 ⇒ plain mean 3.
        assert_eq!(ps.params, pv(&[-3.0]));
    }

    #[test]
    fn update_guard_quarantines_nonfinite_updates() {
        let mut guard = UpdateGuard::new(8.0);
        assert!(guard.admit(&pv(&[1.0, 0.0])));
        assert!(!guard.admit(&pv(&[f32::NAN, 0.0])));
        assert!(!guard.admit(&pv(&[0.0, f32::INFINITY])));
        assert_eq!(guard.accepted, 1);
        assert_eq!(guard.quarantined, 2);
    }

    #[test]
    fn update_guard_bounds_norm_against_accepted_history() {
        let mut guard = UpdateGuard::new(8.0);
        // Build up a unit-norm history.
        for _ in 0..5 {
            assert!(guard.admit(&pv(&[1.0, 0.0])));
        }
        // 100× the median is quarantined; 2× passes.
        assert!(!guard.admit(&pv(&[100.0, 0.0])));
        assert!(guard.admit(&pv(&[2.0, 0.0])));
        assert_eq!(guard.accepted, 6);
        assert_eq!(guard.quarantined, 1);
        // The quarantined norm never entered the history: the median
        // is still ~1, so a follow-up blow-up is also rejected.
        assert!(!guard.admit(&pv(&[50.0, 0.0])));
    }

    #[test]
    fn update_guard_first_push_defines_the_scale() {
        // No history ⇒ only the finite check applies, whatever the norm.
        let mut guard = UpdateGuard::new(2.0);
        assert!(guard.admit(&pv(&[1000.0])));
        // An all-zero history must not divide-by-zero or reject.
        let mut zg = UpdateGuard::new(2.0);
        assert!(zg.admit(&pv(&[0.0, 0.0])));
        assert!(zg.admit(&pv(&[5.0, 0.0])));
    }

    #[test]
    fn better_worker_loss_pulls_global_toward_its_gradient() {
        // Two pushes with identical G magnitude but the PS's stored
        // loss is large ⇒ the incoming (lower-loss) gradient dominates.
        let (mut rt, probe) = probe_for_mock();
        let w0 = init_params(rt.meta(), 4);
        let mut ps = PsState::new(w0.clone(), 0.1);
        // Seed ς with a poor gradient: zero vector evaluated high loss.
        let g_bad = ParamVec::zeros_like(&w0);
        ps.loss_based_sgd(&g_bad, 2.0, &mut rt, &probe).unwrap();
        // Force the stored global loss to be terrible.
        ps.loss = 100.0;
        let mut g_good = ParamVec::zeros_like(&w0);
        g_good.tensors[0].data_mut()[0] = 1.0;
        ps.loss_based_sgd(&g_good, 0.1, &mut rt, &probe).unwrap();
        let s = ps.sigma.as_ref().unwrap().tensors[0].data()[0];
        // W₂/(W₁+W₂) with L=100 vs L_temp≈2.3 ≈ 0.98 ⇒ s ≈ 0.98·1.0.
        assert!(s > 0.8, "sigma {s}");
    }
}
