//! The worker core: local SGD over the assigned working set, test-loss
//! probing, the GUP gate, and the cumulative-G bookkeeping of Alg. 2.
//!
//! One **local iteration** (the unit the paper counts) = `E·DSS/MBS`
//! mini-batch steps over the working set, followed by one probe
//! evaluation.  The simulator caps the *executed* steps at `steps_cap`
//! (compute subsampling, DESIGN.md §5) while virtual time always
//! charges the full Eq. 3 cost.

use anyhow::Result;

use crate::data::{
    BatchSampler, DataSource, Dataset, Probe, Shard, Source, StaticSource,
    StreamSource,
};
use crate::gup::{GateDecision, Gup};
use crate::model::ModelState;
use crate::runtime::ModelRuntime;
use crate::tensor::{BufferPool, ParamVec};

/// Per-worker training state.
#[derive(Debug, Clone)]
pub struct WorkerCore {
    pub id: usize,
    pub state: ModelState,
    pub gup: Gup,
    /// Where training samples come from (DESIGN.md §16): the static
    /// PS-shipped working set, or a bounded streaming replay buffer.
    pub source: Source,
    pub shard: Shard,
    /// Current allocation.
    pub dss: usize,
    pub mbs: usize,
    /// Local iterations completed.
    pub iters: u64,
    /// Times this worker requested/received the global model —
    /// the denominator of the WI metric (Eq. 7).
    pub model_requests: u64,
    /// Last probe loss (test loss of the local model).
    pub last_loss: f32,
    pub last_correct: f32,
    /// Driver flag: the last iteration's gate fired and the push is in
    /// flight (set by event-driven drivers between compute and send).
    pub last_push_pending: bool,
}

/// What one local iteration produced.
#[derive(Debug, Clone, Copy)]
pub struct IterOut {
    pub test_loss: f32,
    pub test_correct: f32,
    pub train_loss: f32,
    pub gate: GateDecision,
    /// Real mini-batch steps executed (≤ steps_cap).
    pub steps_run: usize,
    /// Mini-batch steps the cost model charges (E·DSS/MBS).
    pub steps_modeled: usize,
}

impl WorkerCore {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        id: usize,
        init: ParamVec,
        gup: Gup,
        shard: Shard,
        dss: usize,
        mbs: usize,
        seed: u64,
    ) -> Self {
        let mut sampler = BatchSampler::new(seed, id);
        sampler.refill(&shard.pool, dss);
        WorkerCore {
            id,
            state: ModelState::new(init),
            gup,
            source: Source::Static(StaticSource::new(sampler)),
            shard,
            dss,
            mbs,
            iters: 0,
            model_requests: 0,
            last_loss: f32::INFINITY,
            last_correct: 0.0,
            last_push_pending: false,
        }
    }

    /// Apply a (re)allocation from the PS: new DSS/MBS and a fresh
    /// working set (static) or a rebound shard stream (streaming).
    pub fn assign(&mut self, dss: usize, mbs: usize) {
        self.dss = dss.max(1);
        self.mbs = mbs.max(1);
        self.source.assign_pool(&self.shard.pool, self.dss);
    }

    /// Swap the static source for a streaming one: samples now arrive
    /// over virtual time into a bounded buffer, and the worker only
    /// trains when [`WorkerCore::data_ready`] holds.
    pub fn make_streaming(&mut self, capacity: usize, seed: u64) {
        self.source = Source::Stream(StreamSource::new(
            seed,
            self.id,
            &self.shard.pool,
            capacity,
        ));
    }

    /// Does the source hold enough samples for one local iteration?
    pub fn data_ready(&self) -> bool {
        self.source.ready(self.dss, self.mbs)
    }

    /// Adopt the global model.
    pub fn adopt_global(&mut self, global: &ParamVec, version: u64) {
        self.state.refresh(global, version);
        self.model_requests += 1;
    }

    /// Run one local iteration: `min(E·DSS/MBS, steps_cap)` real train
    /// steps + one probe eval + the GUP decision.
    ///
    /// The fast path (DESIGN.md §13): every training step reads a
    /// contiguous batch view out of the sampler's pre-gathered slab and
    /// updates the model in place through
    /// [`ModelRuntime::train_step_in_place`], with the gradient
    /// accumulator leased from `pool` — after warmup the whole
    /// iteration (steps + probe eval) performs **zero heap
    /// allocations** (`tests/alloc_hotpath.rs`).
    #[allow(clippy::too_many_arguments)]
    pub fn local_iteration(
        &mut self,
        rt: &mut dyn ModelRuntime,
        ds: &Dataset,
        probe: &Probe,
        pool: &mut BufferPool,
        epochs: usize,
        lr: f32,
        mu: f32,
        steps_cap: usize,
    ) -> Result<IterOut> {
        let exec_mbs = rt.meta().clamp_train_batch(self.mbs);
        let steps_modeled =
            ((epochs * self.dss) as f64 / self.mbs as f64).ceil().max(1.0) as usize;
        let steps_run = steps_modeled.min(steps_cap).max(1);

        self.source.begin_iteration(ds, self.dss, self.mbs);
        let mut grad = pool.acquire_like(&self.state.params);
        let mut train_loss = 0f32;
        let mut step_err = None;
        for _ in 0..steps_run {
            let (x, y) = self.source.next_batch(exec_mbs);
            match rt.train_step_in_place(
                &mut self.state.params,
                &mut self.state.momentum,
                &mut grad,
                x,
                y,
                exec_mbs,
                lr,
                mu,
            ) {
                Ok(st) => train_loss = st.loss,
                Err(e) => {
                    step_err = Some(e);
                    break;
                }
            }
        }
        pool.release(grad);
        if let Some(e) = step_err {
            return Err(e);
        }
        self.source.end_iteration(self.dss, self.mbs);

        let ev = rt.eval_step(&self.state.params, &probe.x, &probe.y)?;
        self.last_loss = ev.loss;
        self.last_correct = ev.correct;
        self.iters += 1;

        let gate = self.gup.observe(ev.loss as f64);
        Ok(IterOut {
            test_loss: ev.loss,
            test_correct: ev.correct,
            train_loss,
            gate,
            steps_run,
            steps_modeled,
        })
    }

    /// Alg. 2 Worker-SGD: the cumulative gradient G from the shared
    /// baseline w₀.
    pub fn cumulative_g(&self, w0: &ParamVec, eta: f32) -> ParamVec {
        self.state.cumulative_g(w0, eta)
    }

    /// Borrow-based variant of [`WorkerCore::cumulative_g`] writing
    /// into a pool-leased buffer (the Hermes driver's push path).
    pub fn cumulative_g_into(&self, w0: &ParamVec, eta: f32, out: &mut ParamVec) {
        self.state.cumulative_g_into(w0, eta, out);
    }

    /// Worker independence (Eq. 7): local iterations per global-model
    /// request.
    pub fn wi(&self) -> f64 {
        self.iters as f64 / self.model_requests.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{partition_pools, DataKind, Partition};
    use crate::gup::Gup;
    use crate::runtime::{init_params, MockRuntime};

    fn setup() -> (MockRuntime, Dataset, Probe, BufferPool, WorkerCore) {
        let rt = MockRuntime::new();
        let ds = Dataset::synth(DataKind::MockSet, 1200, 21);
        let (train, test) = ds.split(0.85, 21);
        let probe = Probe::build(&ds, &test, 128, 21);
        let shard =
            partition_pools(&ds, &train, 1, Partition::Iid, 21).remove(0);
        let init = init_params(rt.meta(), 21);
        let gup = Gup::new(10, -1.3, 0.1, 5, true);
        let w = WorkerCore::new(0, init, gup, shard, 256, 16, 21);
        (rt, ds, probe, BufferPool::new(), w)
    }

    #[test]
    fn iterations_learn_and_count() {
        let (mut rt, ds, probe, mut pool, mut w) = setup();
        let mut first = 0f32;
        let mut last = 0f32;
        for i in 0..30 {
            let out = w
                .local_iteration(&mut rt, &ds, &probe, &mut pool, 1, 0.5, 0.0, 4)
                .unwrap();
            if i == 0 {
                first = out.test_loss;
            }
            last = out.test_loss;
            assert_eq!(out.steps_run, 4); // 256/16 = 16 capped at 4
            assert_eq!(out.steps_modeled, 16);
        }
        assert_eq!(w.iters, 30);
        assert!(last < first, "no learning {first} → {last}");
    }

    #[test]
    fn assign_changes_step_budget() {
        let (mut rt, ds, probe, mut pool, mut w) = setup();
        w.assign(64, 32);
        let out = w
            .local_iteration(&mut rt, &ds, &probe, &mut pool, 1, 0.1, 0.0, 100)
            .unwrap();
        assert_eq!(out.steps_modeled, 2); // 64/32
        assert_eq!(out.steps_run, 2);
        assert_eq!(w.source.active_len(), 64);
    }

    #[test]
    fn streaming_worker_gates_on_arrivals_and_consumes_them() {
        let (mut rt, ds, probe, mut pool, mut w) = setup();
        w.assign(64, 16);
        w.make_streaming(128, 21);
        assert!(!w.data_ready(), "empty buffer must gate the iteration");
        w.source.arrive(64);
        assert!(w.data_ready());
        w.local_iteration(&mut rt, &ds, &probe, &mut pool, 1, 0.3, 0.0, 4)
            .unwrap();
        assert_eq!(w.iters, 1);
        // The iteration consumed its working set: gated again.
        assert!(!w.data_ready());
        assert_eq!(w.source.stream().unwrap().buffered(), 0);
        // Deterministic: a clone fed the same arrivals trains on the
        // same samples bit-for-bit.
        let mut a = setup().4;
        a.assign(64, 16);
        a.make_streaming(128, 21);
        let mut b = a.clone();
        a.source.arrive(70);
        b.source.arrive(70);
        let oa = a
            .local_iteration(&mut rt, &ds, &probe, &mut pool, 1, 0.3, 0.0, 4)
            .unwrap();
        let ob = b
            .local_iteration(&mut rt, &ds, &probe, &mut pool, 1, 0.3, 0.0, 4)
            .unwrap();
        assert_eq!(oa.test_loss.to_bits(), ob.test_loss.to_bits());
        assert_eq!(oa.train_loss.to_bits(), ob.train_loss.to_bits());
    }

    #[test]
    fn adopt_global_counts_model_requests_and_wi() {
        let (mut rt, ds, probe, mut pool, mut w) = setup();
        for _ in 0..6 {
            w.local_iteration(&mut rt, &ds, &probe, &mut pool, 1, 0.2, 0.0, 2)
                .unwrap();
        }
        let g = init_params(rt.meta(), 99);
        w.adopt_global(&g, 5);
        assert_eq!(w.state.version, 5);
        assert_eq!(w.model_requests, 1);
        assert!((w.wi() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn cumulative_g_reconstructs_params() {
        let (mut rt, ds, probe, mut pool, mut w) = setup();
        let w0 = w.state.params.clone();
        let eta = 0.3f32;
        for _ in 0..5 {
            w.local_iteration(&mut rt, &ds, &probe, &mut pool, 1, eta, 0.0, 3)
                .unwrap();
        }
        let g = w.cumulative_g(&w0, eta);
        let rebuilt = ModelState::from_cumulative(&w0, &g, eta);
        for (a, b) in rebuilt
            .tensors
            .iter()
            .flat_map(|t| t.data())
            .zip(w.state.params.tensors.iter().flat_map(|t| t.data()))
        {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn gate_fires_during_early_learning() {
        let (mut rt, ds, probe, mut pool, mut w) = setup();
        let mut pushes = 0;
        for _ in 0..40 {
            let out = w
                .local_iteration(&mut rt, &ds, &probe, &mut pool, 1, 0.5, 0.0, 4)
                .unwrap();
            if out.gate.push {
                pushes += 1;
            }
        }
        assert!(pushes > 0, "GUP never fired during steep learning");
        assert!(
            pushes < 40,
            "GUP fired every iteration — gate not selective"
        );
    }

    #[test]
    fn grad_scratch_is_pool_served_after_warmup() {
        let (mut rt, ds, probe, mut pool, mut w) = setup();
        w.local_iteration(&mut rt, &ds, &probe, &mut pool, 1, 0.3, 0.0, 2)
            .unwrap();
        // The leased gradient buffer was released back.
        assert_eq!(pool.available(), 1);
        w.local_iteration(&mut rt, &ds, &probe, &mut pool, 1, 0.3, 0.0, 2)
            .unwrap();
        assert_eq!(pool.available(), 1, "lease/release cycle must balance");
    }
}
