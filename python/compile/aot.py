"""AOT lowering: JAX (L2) + Pallas (L1) → HLO text artifacts for Rust (L3).

Emits HLO **text**, not a serialized ``HloModuleProto``: jax ≥ 0.5 writes
protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

One artifact per (model, kind, batch size):

    artifacts/{model}_{train|eval}_b{batch}.hlo.txt

plus ``artifacts/manifest.json`` describing parameter shapes and the
exact input/output ordering, which the Rust runtime consumes.  Python
runs only here — never on the request path.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import hashlib
import json
import os
import struct
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import (
    MODELS,
    example_args_eval,
    example_args_train,
    init_params,
    make_eval_step,
    make_train_step,
)

# Mini-batch sizes compiled per model.  The paper's dual binary search
# walks MBS ∈ {2, 4, …, 256}; the runtime clamps the searched MBS to the
# nearest compiled size (documented in DESIGN.md §3).  AlexNet gets a
# narrower set to bound artifact build time.
TRAIN_BATCHES = {"cnn": (8, 16, 32, 64), "alexnet": (16, 32)}
EVAL_BATCH = 128


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(path: str, text: str) -> dict:
    with open(path, "w") as f:
        f.write(text)
    digest = hashlib.sha256(text.encode()).hexdigest()[:16]
    return {"bytes": len(text), "sha256_16": digest}


GOLDEN_BATCH = 16


def _write_golden(out_dir: str, name: str, spec) -> dict:
    """Cross-language contract fixture: deterministic inputs and the
    jit-executed expected outputs of one train step, as a flat little-
    endian f32 blob + a JSON index.  The Rust runtime integration test
    loads the HLO artifact, runs the same inputs, and must match."""
    batch = GOLDEN_BATCH
    n = len(spec.param_shapes)
    params = init_params(spec, jax.random.PRNGKey(0))
    mom = [jnp.zeros_like(p) for p in params]
    h, w, c = spec.input_shape
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, h, w, c))
    y = jax.random.randint(jax.random.PRNGKey(2), (batch,), 0, 10)
    lr, mu = 0.05, 0.9
    out = make_train_step(spec)(
        *params, *mom, x, y, jnp.float32(lr), jnp.float32(mu)
    )
    new_params, loss, correct = out[:n], out[2 * n], out[2 * n + 1]

    blob_path = os.path.join(out_dir, f"golden_{name}.bin")
    index = {
        "batch": batch,
        "lr": lr,
        "momentum": mu,
        "labels": [int(v) for v in np.asarray(y)],
        "loss": float(loss),
        "correct": float(correct),
        "sections": [],
    }
    with open(blob_path, "wb") as f:
        offset = 0

        def put(tag, arr):
            nonlocal offset
            a = np.asarray(arr, dtype=np.float32).ravel()
            f.write(struct.pack(f"<{a.size}f", *a.tolist()))
            index["sections"].append(
                {"tag": tag, "offset": offset, "len": int(a.size)}
            )
            offset += a.size

        for i, p in enumerate(params):
            put(f"param{i}", p)
        put("x", x)
        for i, p in enumerate(new_params):
            put(f"new_param{i}", p)
    index["blob"] = f"golden_{name}.bin"
    with open(os.path.join(out_dir, f"golden_{name}.json"), "w") as f:
        json.dump(index, f)
    return {"blob": index["blob"], "index": f"golden_{name}.json"}


def build(out_dir: str, models=None, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": 1, "eval_batch": EVAL_BATCH, "models": {}}
    for name, spec in MODELS.items():
        if models and name not in models:
            continue
        entry = {
            "input_shape": list(spec.input_shape),
            "num_classes": spec.num_classes,
            "param_shapes": [list(s) for s in spec.param_shapes],
            "param_count": spec.param_count,
            "layers": [
                {
                    "kind": l.kind,
                    "shape": list(l.shape),
                    "act": l.act,
                    "pool": l.pool,
                }
                for l in spec.layers
            ],
            "train": {},
            "eval": {},
        }
        train_step = make_train_step(spec)
        for batch in TRAIN_BATCHES[name]:
            t0 = time.time()
            lowered = jax.jit(train_step).lower(
                *example_args_train(spec, batch)
            )
            fname = f"{name}_train_b{batch}.hlo.txt"
            info = _write(os.path.join(out_dir, fname), to_hlo_text(lowered))
            info["path"] = fname
            entry["train"][str(batch)] = info
            if verbose:
                print(
                    f"[aot] {fname}: {info['bytes']} bytes "
                    f"({time.time() - t0:.1f}s)"
                )
        eval_step = make_eval_step(spec)
        t0 = time.time()
        lowered = jax.jit(eval_step).lower(
            *example_args_eval(spec, EVAL_BATCH)
        )
        fname = f"{name}_eval_b{EVAL_BATCH}.hlo.txt"
        info = _write(os.path.join(out_dir, fname), to_hlo_text(lowered))
        info["path"] = fname
        entry["eval"][str(EVAL_BATCH)] = info
        if verbose:
            print(
                f"[aot] {fname}: {info['bytes']} bytes "
                f"({time.time() - t0:.1f}s)"
            )
        if GOLDEN_BATCH in TRAIN_BATCHES[name]:
            entry["golden"] = _write_golden(out_dir, name, spec)
            if verbose:
                print(f"[aot] golden_{name}.bin")
        manifest["models"][name] = entry

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    if verbose:
        print(f"[aot] wrote {os.path.join(out_dir, 'manifest.json')}")
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts")
    parser.add_argument(
        "--models", nargs="*", default=None, help="subset of models to build"
    )
    args = parser.parse_args()
    build(args.out, models=args.models)


if __name__ == "__main__":
    main()
