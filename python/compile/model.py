"""Layer-2 JAX models for the Hermes reproduction (build-time only).

Two models, matching §V-A of the paper:

- ``cnn``      — ~110K-parameter CNN for the IID (MNIST-like) dataset,
                 plain SGD (η = 0.1 in Table I).
- ``alexnet``  — ~990K-parameter downsized AlexNet for the non-IID
                 (CIFAR-like) dataset, SGD + momentum (η = 0.001,
                 momentum = 0.9 in Table I).

All dense/conv compute routes through the Layer-1 Pallas kernels so the
AOT artifact contains the kernel schedule.  Parameters are a flat *list*
of arrays in a fixed order (the Rust runtime mirrors that order via
``artifacts/manifest.json``).

``train_step`` performs fwd + bwd + SGD(M) update in one XLA program and
returns (new_params…, new_momentum…, loss, correct); ``eval_step``
returns (loss, correct).  Learning rate and momentum are runtime scalar
inputs so one artifact serves every hyper-parameter configuration.
"""

from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import conv2d_bias_act, matmul_bias_act
from .kernels.ref import maxpool2x2_ref as maxpool2x2


@dataclass(frozen=True)
class LayerSpec:
    kind: str  # "conv" | "dense"
    shape: Tuple[int, ...]  # weight shape
    act: str  # "relu" | "none"
    pool: bool = False  # 2x2 maxpool after activation (conv only)


@dataclass(frozen=True)
class ModelSpec:
    name: str
    input_shape: Tuple[int, int, int]  # H, W, C
    num_classes: int
    layers: Tuple[LayerSpec, ...] = field(default=())

    @property
    def param_shapes(self) -> List[Tuple[int, ...]]:
        """Weight and bias shapes, interleaved [w0, b0, w1, b1, …]."""
        out: List[Tuple[int, ...]] = []
        for layer in self.layers:
            out.append(layer.shape)
            out.append((layer.shape[-1],))
        return out

    @property
    def param_count(self) -> int:
        total = 0
        for s in self.param_shapes:
            n = 1
            for d in s:
                n *= d
            total += n
        return total


def _cnn_spec() -> ModelSpec:
    """~110K params: 28×28×1 → conv8 → pool → conv16 → pool → 136 → 10."""
    return ModelSpec(
        name="cnn",
        input_shape=(28, 28, 1),
        num_classes=10,
        layers=(
            LayerSpec("conv", (3, 3, 1, 8), "relu", pool=True),
            LayerSpec("conv", (3, 3, 8, 16), "relu", pool=True),
            LayerSpec("dense", (7 * 7 * 16, 136), "relu"),
            LayerSpec("dense", (136, 10), "none"),
        ),
    )


def _alexnet_spec() -> ModelSpec:
    """~990K params: downsized AlexNet for 32×32×3 (5 convs, 3 dense)."""
    return ModelSpec(
        name="alexnet",
        input_shape=(32, 32, 3),
        num_classes=10,
        layers=(
            LayerSpec("conv", (3, 3, 3, 24), "relu", pool=True),
            LayerSpec("conv", (3, 3, 24, 48), "relu", pool=True),
            LayerSpec("conv", (3, 3, 48, 64), "relu"),
            LayerSpec("conv", (3, 3, 64, 64), "relu"),
            LayerSpec("conv", (3, 3, 64, 48), "relu"),
            LayerSpec("dense", (8 * 8 * 48, 284), "relu"),
            LayerSpec("dense", (284, 64), "relu"),
            LayerSpec("dense", (64, 10), "none"),
        ),
    )


MODELS = {"cnn": _cnn_spec(), "alexnet": _alexnet_spec()}


def init_params(spec: ModelSpec, key) -> List[jnp.ndarray]:
    """He-normal weights, zero biases (the Rust host mirrors this)."""
    params = []
    for layer in spec.layers:
        key, sub = jax.random.split(key)
        fan_in = 1
        for d in layer.shape[:-1]:
            fan_in *= d
        std = jnp.sqrt(2.0 / fan_in)
        params.append(jax.random.normal(sub, layer.shape, jnp.float32) * std)
        params.append(jnp.zeros((layer.shape[-1],), jnp.float32))
    return params


def forward(spec: ModelSpec, params: List[jnp.ndarray], x: jnp.ndarray):
    """Logits for a batch x:[B,H,W,C]."""
    h = x
    idx = 0
    for layer in spec.layers:
        w, b = params[idx], params[idx + 1]
        idx += 2
        if layer.kind == "conv":
            h = conv2d_bias_act(h, w, b, layer.act)
            if layer.pool:
                h = maxpool2x2(h)
        else:
            if h.ndim > 2:
                h = h.reshape(h.shape[0], -1)
            h = matmul_bias_act(h, w, b, layer.act)
    return h


def loss_and_correct(spec: ModelSpec, params, x, y):
    """(mean xent loss, #correct) for a labelled batch."""
    logits = forward(spec, params, x)
    logz = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logz, y[:, None], axis=-1)[:, 0]
    correct = (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32).sum()
    return nll.mean(), correct


def make_train_step(spec: ModelSpec):
    """fwd + bwd + SGD(M) update as one function of flat inputs.

    Signature: (params… , momentum… , x, y, lr, mu) →
               (new_params… , new_momentum… , loss, correct).
    Momentum buffers are always present; plain SGD passes mu = 0 (the
    buffers then carry the raw gradients, which the coordinator ignores).
    """
    n = len(spec.param_shapes)

    def train_step(*args):
        params = list(args[:n])
        mom = list(args[n : 2 * n])
        x, y, lr, mu = args[2 * n :]

        def loss_fn(ps):
            loss, correct = loss_and_correct(spec, ps, x, y)
            return loss, correct

        (loss, correct), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params
        )
        new_mom = [mu * m + g for m, g in zip(mom, grads)]
        new_params = [p - lr * v for p, v in zip(params, new_mom)]
        return tuple(new_params) + tuple(new_mom) + (loss, correct)

    return train_step


def make_eval_step(spec: ModelSpec):
    """(params…, x, y) → (loss, correct)."""
    n = len(spec.param_shapes)

    def eval_step(*args):
        params = list(args[:n])
        x, y = args[n], args[n + 1]
        return loss_and_correct(spec, params, x, y)

    return eval_step


def example_args_train(spec: ModelSpec, batch: int):
    n_shapes = spec.param_shapes
    h, w, c = spec.input_shape
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in n_shapes]
    args += [jax.ShapeDtypeStruct(s, jnp.float32) for s in n_shapes]
    args.append(jax.ShapeDtypeStruct((batch, h, w, c), jnp.float32))
    args.append(jax.ShapeDtypeStruct((batch,), jnp.int32))
    args.append(jax.ShapeDtypeStruct((), jnp.float32))  # lr
    args.append(jax.ShapeDtypeStruct((), jnp.float32))  # momentum
    return args


def example_args_eval(spec: ModelSpec, batch: int):
    h, w, c = spec.input_shape
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in spec.param_shapes]
    args.append(jax.ShapeDtypeStruct((batch, h, w, c), jnp.float32))
    args.append(jax.ShapeDtypeStruct((batch,), jnp.int32))
    return args
