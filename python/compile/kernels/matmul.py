"""Fused tiled matmul + bias + activation as a Pallas kernel.

This is the Layer-1 compute hot-spot of the reproduction: both models'
dense layers and (via im2col in :mod:`conv2d`) all conv layers reduce to
this kernel, and its custom VJP routes the backward matmuls
(dx = g @ wᵀ, dw = xᵀ @ g) through the same kernel.

TPU thinking (DESIGN.md §Hardware-Adaptation): the grid walks (M/bm,
N/bn, K/bk) tiles; each program keeps one (bm, bn) output tile resident
in VMEM while streaming (bm, bk) / (bk, bn) input tiles from HBM, and
the inner ``jnp.dot`` maps onto the MXU.  Default blocks are 128-aligned
— the MXU systolic array is 128×128 — and shrink (8-aligned) only when a
dimension is smaller than a full tile.  VMEM footprint per program is
(bm·bk + bk·bn + bm·bn + bn)·4 B ≈ 192 KiB at the 128³ default, well
under the ~16 MiB/core budget, leaving room for double-buffering.

``interpret=True`` everywhere: the CPU PJRT backend executes the
interpreter lowering; a real TPU build would flip this flag only.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Two schedules (DESIGN.md §Hardware-Adaptation + §Perf):
#
# * TPU_BLOCKS — the 128-aligned MXU tiling a real-TPU build would use
#   (VMEM ≈ 192 KiB/program, double-buffer friendly).
# * CPU_BLOCKS — coarse whole(ish)-array blocks for the interpret-mode
#   CPU artifact.  The Pallas interpreter charges ~1.5 ms of
#   dynamic-slice/DUS machinery per grid step on this host (measured:
#   128³ tiling = 50.6 ms vs 1.0 ms at grid≈1 for a (4096,216,48) GEMM,
#   jnp.dot baseline 0.76 ms), so the CPU schedule minimizes grid steps.
#   Numerical equivalence of the two schedules is pytest-enforced.
TPU_BLOCKS = (128, 128, 128)
CPU_BLOCKS = (4096, 512, 2048)
DEFAULT_BM, DEFAULT_BN, DEFAULT_BK = CPU_BLOCKS


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_block(dim: int, cap: int) -> int:
    """Block size for one dimension: a full `cap` tile when the dim is
    large enough, otherwise the whole (8-aligned) dimension."""
    if dim >= cap:
        return cap
    return _round_up(max(dim, 1), 8)


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, act: str):
    """One (bm, bn) output tile; grid axis 2 walks the K tiles."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        y = o_ref[...] + b_ref[...]
        if act == "relu":
            y = jnp.maximum(y, 0.0)
        o_ref[...] = y


def _matmul_raw(x, w, b, act: str, bm: int, bn: int, bk: int):
    """Pad to tile multiples, run the kernel, slice the result back."""
    if act not in ("relu", "none"):
        raise ValueError(f"unknown act {act!r}")
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    assert b.shape == (n,), b.shape

    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)

    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(b, (0, np_ - n))
    nk = kp // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk, act=act),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def matmul_bias_act(
    x,
    w,
    b,
    act: str = "relu",
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
):
    """y = act(x @ w + b) with x:[M,K], w:[K,N], b:[N].

    Differentiable w.r.t. (x, w, b); the backward pass reuses the same
    Pallas kernel for both backward matmuls.
    """
    return _matmul_raw(x, w, b, act, bm, bn, bk)


def _mm_fwd(x, w, b, act, bm, bn, bk):
    y = _matmul_raw(x, w, b, act, bm, bn, bk)
    return y, (x, w, y)


def _mm_bwd(act, bm, bn, bk, res, g):
    x, w, y = res
    if act == "relu":
        g = g * (y > 0.0).astype(g.dtype)
    n = w.shape[1]
    zn = jnp.zeros((x.shape[0],), jnp.float32)
    zk = jnp.zeros((w.shape[0],), jnp.float32)
    # dx = g @ wᵀ, dw = xᵀ @ g — both through the Pallas kernel.
    dx = _matmul_raw(g, w.T, zk, "none", bm, bk, bn)
    dw = _matmul_raw(x.T, g, jnp.zeros((n,), jnp.float32), "none", bk, bn, bm)
    db = g.sum(axis=0)
    del zn
    return dx, dw, db


matmul_bias_act.defvjp(_mm_fwd, _mm_bwd)
