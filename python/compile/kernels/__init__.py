"""Layer-1 Pallas kernels for the Hermes reproduction.

Every FLOP-heavy op in the Layer-2 model routes through these kernels so
that the AOT-lowered HLO contains the kernel loops, not ad-hoc jnp ops:

- :mod:`matmul`  — fused tiled matmul + bias + optional ReLU, with a
  custom VJP whose backward matmuls are themselves Pallas kernels.
- :mod:`conv2d`  — stride-1 'same' conv expressed as an unrolled
  shift-and-matmul (im2col-in-VMEM) kernel, custom VJP included.
- :mod:`ref`     — pure-jnp oracles used by pytest/hypothesis.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, so the interpreter lowering (a fori-loop of
dynamic-slice / dot / dynamic-update-slice over the grid) is what lands
in the HLO artifact.  Block shapes are still chosen MXU/VMEM-first — see
DESIGN.md §Hardware-Adaptation.
"""

from . import ref  # noqa: F401
from .matmul import matmul_bias_act  # noqa: F401
from .conv2d import conv2d_bias_act  # noqa: F401
