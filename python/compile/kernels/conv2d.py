"""Stride-1 'same' conv2d + bias + activation as a Pallas kernel.

The kernel materializes one batch-tile of the (pre-padded) input in
VMEM, performs **im2col in VMEM** — the KH·KW shifted H×W windows are
concatenated into a [bb·H·W, KH·KW·C] patch matrix that never touches
HBM — and contracts it against the reshaped weights with one MXU-shaped
``jnp.dot``.  This is the TPU re-think of the paper's (CPU, TensorFlow)
conv: a GPU port's threadblock decomposition becomes a batch-tile grid
where BlockSpec expresses the HBM↔VMEM schedule and the single big GEMM
feeds the systolic array at full tile occupancy.

VMEM per program at batch tile bb on H×W×C images:
  input  bb·(H+2)·(W+2)·C·4 B, patches ≈ 9× the input, plus the
  [bb·H·W, O] accumulator — bb=8 on 32×32×48 ≈ 8.5 MiB, inside the
  ~16 MiB/core budget.  The CPU-interpret artifact uses bb = full batch
  to minimize Pallas-interpreter grid overhead (see matmul.py).

Backward is a custom VJP:
  db = Σ g;   dw = patchesᵀ @ g  (one Pallas matmul);
  dx = conv(g, flip(w) with channels swapped)  (this same kernel).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _matmul_raw

# Batch tile: a real-TPU build would use 8; the CPU-interpret artifact
# uses the whole batch (grid = 1) to avoid per-grid-step interpreter
# overhead.  `None` means "whole batch".
TPU_BB = 8
DEFAULT_BB = None


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, hh, ww, kh, kw, act):
    """One batch tile.  x_ref:[bb, H+kh-1, W+kw-1, C] (pre-padded),
    w_ref:[kh*kw*C, O], o_ref:[bb, H, W, O]."""
    x = x_ref[...]
    bb, cin = x.shape[0], x.shape[3]
    cout = o_ref.shape[3]
    # im2col in VMEM: [bb, H, W, kh*kw*C] patch tensor.
    windows = [
        x[:, i : i + hh, j : j + ww, :] for i in range(kh) for j in range(kw)
    ]
    patches = jnp.concatenate(windows, axis=3).reshape(
        bb * hh * ww, kh * kw * cin
    )
    y = jnp.dot(patches, w_ref[...], preferred_element_type=jnp.float32)
    y = y.reshape(bb, hh, ww, cout) + b_ref[...]
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    o_ref[...] = y


def _conv_raw(x, w, b, act: str, bb):
    if act not in ("relu", "none"):
        raise ValueError(f"unknown act {act!r}")
    batch, hh, ww, cin = x.shape
    kh, kw, cin2, cout = w.shape
    assert cin == cin2, (x.shape, w.shape)
    assert kh % 2 == 1 and kw % 2 == 1, "odd taps only ('same' padding)"
    ph, pw = kh // 2, kw // 2

    bb = batch if bb is None else min(bb, batch)
    bp = _round_up(batch, bb)
    # Zero-pad: batch up to the tile multiple, spatial for 'same'.
    xp = jnp.pad(x, ((0, bp - batch), (ph, ph), (pw, pw), (0, 0)))
    wm = w.reshape(kh * kw * cin, cout)

    out = pl.pallas_call(
        functools.partial(_conv_kernel, hh=hh, ww=ww, kh=kh, kw=kw, act=act),
        grid=(bp // bb,),
        in_specs=[
            pl.BlockSpec(
                (bb, hh + kh - 1, ww + kw - 1, cin), lambda i: (i, 0, 0, 0)
            ),
            pl.BlockSpec((kh * kw * cin, cout), lambda i: (0, 0)),
            pl.BlockSpec((cout,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, hh, ww, cout), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, hh, ww, cout), jnp.float32),
        interpret=True,
    )(xp, wm, b)
    return out[:batch]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def conv2d_bias_act(x, w, b, act: str = "relu", bb=DEFAULT_BB):
    """y = act(conv2d_same(x, w) + b); x:[B,H,W,C], w:[KH,KW,C,O], b:[O]."""
    return _conv_raw(x, w, b, act, bb)


def _conv_fwd(x, w, b, act, bb):
    y = _conv_raw(x, w, b, act, bb)
    return y, (x, w, y)


def _conv_bwd(act, bb, res, g):
    x, w, y = res
    if act == "relu":
        g = g * (y > 0.0).astype(g.dtype)
    batch, hh, ww, cin = x.shape
    kh, kw, _, cout = w.shape
    ph, pw = kh // 2, kw // 2

    db = g.sum(axis=(0, 1, 2))

    # dw = patchesᵀ @ g — one Pallas matmul over the full im2col matrix.
    xp = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    windows = [
        xp[:, i : i + hh, j : j + ww, :]
        for i in range(kh)
        for j in range(kw)
    ]
    patches = jnp.concatenate(windows, axis=3).reshape(
        batch * hh * ww, kh * kw * cin
    )
    gm = g.reshape(batch * hh * ww, cout)
    zero_n = jnp.zeros((cout,), jnp.float32)
    dw = _matmul_raw(
        patches.T, gm, zero_n, "none", 4096, 512, 4096
    ).reshape(kh, kw, cin, cout)

    # dx = 'same' conv of g with the spatially-flipped, channel-swapped w.
    w_flip = jnp.flip(w, axis=(0, 1)).transpose(0, 1, 3, 2)  # [KH,KW,O,C]
    dx = _conv_raw(g, w_flip, jnp.zeros((cin,), jnp.float32), "none", bb)

    return dx, dw, db


conv2d_bias_act.defvjp(_conv_fwd, _conv_bwd)
