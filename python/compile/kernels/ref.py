"""Pure-jnp oracles for the Pallas kernels.

These are the ground truth for the pytest/hypothesis suites: the Pallas
kernels in :mod:`matmul` / :mod:`conv2d` must agree with these to within
float32 tolerance for every generated shape, and their VJPs must agree
with jax.grad through these references.
"""

import jax
import jax.numpy as jnp


def matmul_bias_act_ref(x, w, b, act: str = "relu"):
    """y = act(x @ w + b); x:[M,K] w:[K,N] b:[N]."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act != "none":
        raise ValueError(f"unknown act {act!r}")
    return y


def conv2d_bias_act_ref(x, w, b, act: str = "relu"):
    """Stride-1 'same' conv; x:[B,H,W,C] w:[KH,KW,C,O] b:[O]."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    ) + b
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act != "none":
        raise ValueError(f"unknown act {act!r}")
    return y


def maxpool2x2_ref(x):
    """2x2 max-pool, stride 2; x:[B,H,W,C] with even H,W."""
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


def softmax_xent_ref(logits, labels):
    """Mean softmax cross-entropy; logits:[B,N], labels:[B] int32."""
    logz = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logz, labels[:, None], axis=-1)[:, 0]
    return nll.mean()
