"""AOT pipeline: HLO text artifacts are loadable, runnable, and agree
with the direct-jit execution (this is the exact interchange contract
the Rust runtime relies on)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot
from compile.model import (
    MODELS,
    example_args_train,
    init_params,
    make_eval_step,
    make_train_step,
)

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_is_parseable_hlo():
    spec = MODELS["cnn"]
    lowered = jax.jit(make_eval_step(spec)).lower(
        *aot.example_args_eval(spec, 8)
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:64]
    assert "ROOT" in text
    # 64-bit-id regression guard: the text parser reassigns ids, so the
    # text itself must not be empty/truncated.
    assert len(text) > 1_000


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
class TestBuiltArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_manifest_structure(self, manifest):
        assert manifest["format"] == 1
        for name, spec in MODELS.items():
            entry = manifest["models"][name]
            assert entry["param_count"] == spec.param_count
            assert [tuple(s) for s in entry["param_shapes"]] == [
                tuple(s) for s in spec.param_shapes
            ]
            assert entry["train"], "no train artifacts"
            assert entry["eval"], "no eval artifacts"

    def test_artifact_files_exist_and_match_digest(self, manifest):
        import hashlib

        for entry in manifest["models"].values():
            for group in ("train", "eval"):
                for info in entry[group].values():
                    path = os.path.join(ART, info["path"])
                    assert os.path.exists(path), path
                    text = open(path).read()
                    assert len(text) == info["bytes"]
                    assert (
                        hashlib.sha256(text.encode()).hexdigest()[:16]
                        == info["sha256_16"]
                    )

    def test_train_artifacts_parse_back_through_xla(self, manifest):
        """Every emitted HLO text must re-parse through the XLA text
        parser (the same parser family the Rust loader uses).  Execution
        equivalence is enforced by the Rust runtime integration test
        against the golden fixtures."""
        for entry in manifest["models"].values():
            for group in ("train", "eval"):
                for info in entry[group].values():
                    text = open(os.path.join(ART, info["path"])).read()
                    mod = xc._xla.hlo_module_from_text(text)
                    assert mod.name, info["path"]

    def test_golden_fixture_is_consistent(self, manifest):
        """The golden blob re-checks against a fresh jit execution."""
        name = "cnn"
        spec = MODELS[name]
        gold = manifest["models"][name].get("golden")
        assert gold, "golden fixture missing"
        with open(os.path.join(ART, f"golden_{name}.json")) as f:
            index = json.load(f)
        blob = np.fromfile(
            os.path.join(ART, index["blob"]), dtype="<f4"
        )
        sections = {s["tag"]: s for s in index["sections"]}

        def get(tag, shape):
            s = sections[tag]
            return blob[s["offset"] : s["offset"] + s["len"]].reshape(shape)

        n = len(spec.param_shapes)
        params = [
            jnp.asarray(get(f"param{i}", tuple(s)))
            for i, s in enumerate(spec.param_shapes)
        ]
        mom = [jnp.zeros_like(p) for p in params]
        h, w, c = spec.input_shape
        x = jnp.asarray(get("x", (index["batch"], h, w, c)))
        y = jnp.asarray(np.array(index["labels"], dtype=np.int32))
        out = make_train_step(spec)(
            *params,
            *mom,
            x,
            y,
            jnp.float32(index["lr"]),
            jnp.float32(index["momentum"]),
        )
        np.testing.assert_allclose(
            float(out[2 * n]), index["loss"], rtol=1e-5
        )
        assert float(out[2 * n + 1]) == index["correct"]
        for i, s in enumerate(spec.param_shapes):
            np.testing.assert_allclose(
                out[i], get(f"new_param{i}", tuple(s)), rtol=1e-4, atol=1e-6
            )


def test_build_subset_into_tmpdir(tmp_path):
    """`build` with a model subset produces a consistent manifest."""
    manifest = aot.build(str(tmp_path), models=["cnn"], verbose=False)
    assert set(manifest["models"]) == {"cnn"}
    assert (tmp_path / "manifest.json").exists()
    listed = {
        info["path"]
        for grp in ("train", "eval")
        for info in manifest["models"]["cnn"][grp].values()
    }
    for path in listed:
        assert (tmp_path / path).exists()
