"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes/regimes; explicit cases pin the block-edge and
padding behaviour.  Both the CPU-interpret (coarse) and TPU (128-tiled)
schedules must agree with the reference — the artifact uses the former,
DESIGN.md's roofline estimate the latter.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import conv2d_bias_act, matmul_bias_act, ref
from compile.kernels.matmul import TPU_BLOCKS

FWD_TOL = dict(rtol=1e-4, atol=1e-4)
BWD_TOL = dict(rtol=1e-3, atol=1e-3)


def _rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape)


# ---------------------------------------------------------------- matmul


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 90),
    n=st.integers(1, 70),
    act=st.sampled_from(["relu", "none"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_forward_matches_ref(m, k, n, act, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    b = _rand(seed + 2, (n,))
    got = matmul_bias_act(x, w, b, act)
    want = ref.matmul_bias_act_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, **FWD_TOL)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(2, 40),
    k=st.integers(2, 60),
    n=st.integers(2, 40),
    act=st.sampled_from(["relu", "none"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_grad_matches_ref(m, k, n, act, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    b = _rand(seed + 2, (n,))

    def f_kernel(x, w, b):
        return (matmul_bias_act(x, w, b, act) ** 2).sum()

    def f_ref(x, w, b):
        return (ref.matmul_bias_act_ref(x, w, b, act) ** 2).sum()

    got = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, b)
    want = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for g, r in zip(got, want):
        np.testing.assert_allclose(g, r, **BWD_TOL)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (1, 1, 1),  # degenerate
        (128, 128, 128),  # exactly one TPU tile
        (129, 257, 130),  # one past the tile edge (padding path)
        (16, 784, 136),  # the CNN fc1 shape
    ],
)
def test_matmul_edge_shapes(m, k, n):
    x = _rand(7, (m, k))
    w = _rand(8, (k, n))
    b = _rand(9, (n,))
    got = matmul_bias_act(x, w, b, "relu")
    want = ref.matmul_bias_act_ref(x, w, b, "relu")
    np.testing.assert_allclose(got, want, **FWD_TOL)


def test_matmul_tpu_schedule_matches_cpu_schedule():
    """The 128-tiled TPU schedule and the coarse CPU schedule are the
    same function (only the HBM↔VMEM walk differs)."""
    x = _rand(1, (150, 300))
    w = _rand(2, (300, 140))
    b = _rand(3, (140,))
    bm, bn, bk = TPU_BLOCKS
    tiled = matmul_bias_act(x, w, b, "relu", bm, bn, bk)
    coarse = matmul_bias_act(x, w, b, "relu")
    # fp32 accumulation order differs between the schedules.
    np.testing.assert_allclose(tiled, coarse, rtol=1e-4, atol=1e-4)


def test_matmul_rejects_unknown_act():
    x = _rand(1, (4, 4))
    with pytest.raises(ValueError):
        matmul_bias_act(x, x, x[0], "gelu")


def test_conv_rejects_unknown_act():
    x = _rand(1, (1, 4, 4, 1))
    w = _rand(2, (3, 3, 1, 2))
    with pytest.raises(ValueError):
        conv2d_bias_act(x, w, jnp.zeros((2,)), "gelu")


# ---------------------------------------------------------------- conv2d


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 9),
    hw=st.integers(4, 14),
    cin=st.integers(1, 6),
    cout=st.integers(1, 8),
    act=st.sampled_from(["relu", "none"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_forward_matches_ref(b, hw, cin, cout, act, seed):
    x = _rand(seed, (b, hw, hw, cin))
    w = _rand(seed + 1, (3, 3, cin, cout), 0.5)
    bias = _rand(seed + 2, (cout,))
    got = conv2d_bias_act(x, w, bias, act)
    want = ref.conv2d_bias_act_ref(x, w, bias, act)
    np.testing.assert_allclose(got, want, **FWD_TOL)


@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 5),
    hw=st.integers(4, 10),
    cin=st.integers(1, 4),
    cout=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv_grad_matches_ref(b, hw, cin, cout, seed):
    x = _rand(seed, (b, hw, hw, cin))
    w = _rand(seed + 1, (3, 3, cin, cout), 0.5)
    bias = _rand(seed + 2, (cout,))

    def f_kernel(x, w, b):
        return (conv2d_bias_act(x, w, b, "relu") ** 2).sum()

    def f_ref(x, w, b):
        return (ref.conv2d_bias_act_ref(x, w, b, "relu") ** 2).sum()

    got = jax.grad(f_kernel, argnums=(0, 1, 2))(x, w, bias)
    want = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, bias)
    for g, r in zip(got, want):
        np.testing.assert_allclose(g, r, **BWD_TOL)


def test_conv_5x5_taps():
    x = _rand(4, (2, 9, 9, 3))
    w = _rand(5, (5, 5, 3, 4), 0.3)
    bias = _rand(6, (4,))
    got = conv2d_bias_act(x, w, bias, "relu")
    want = ref.conv2d_bias_act_ref(x, w, bias, "relu")
    np.testing.assert_allclose(got, want, **FWD_TOL)


def test_conv_batch_tiling_pads_correctly():
    """Batch not divisible by the tile: padded rows must not leak."""
    x = _rand(10, (5, 8, 8, 2))
    w = _rand(11, (3, 3, 2, 3), 0.5)
    bias = _rand(12, (3,))
    got = conv2d_bias_act(x, w, bias, "none", 4)  # bb=4, batch=5
    want = ref.conv2d_bias_act_ref(x, w, bias, "none")
    np.testing.assert_allclose(got, want, **FWD_TOL)


def test_conv_even_taps_rejected():
    x = _rand(1, (1, 4, 4, 1))
    w = _rand(2, (2, 2, 1, 1))
    with pytest.raises(AssertionError):
        conv2d_bias_act(x, w, jnp.zeros((1,)), "none")
