"""L2 correctness: model shapes, parameter counts, train/eval semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.model import (
    MODELS,
    example_args_eval,
    example_args_train,
    forward,
    init_params,
    loss_and_correct,
    make_eval_step,
    make_train_step,
)


@pytest.fixture(scope="module")
def cnn():
    return MODELS["cnn"]


@pytest.fixture(scope="module")
def alexnet():
    return MODELS["alexnet"]


def test_param_counts_match_paper(cnn, alexnet):
    # §V-A: "approximately 110K" / "approximately 990K".
    assert abs(cnn.param_count - 110_000) < 5_000, cnn.param_count
    assert abs(alexnet.param_count - 990_000) < 20_000, alexnet.param_count


def test_param_shapes_interleave_weights_and_biases(cnn):
    shapes = cnn.param_shapes
    assert len(shapes) == 2 * len(cnn.layers)
    for i, layer in enumerate(cnn.layers):
        assert tuple(shapes[2 * i]) == layer.shape
        assert tuple(shapes[2 * i + 1]) == (layer.shape[-1],)


@pytest.mark.parametrize("name", ["cnn", "alexnet"])
def test_forward_shape_and_finite(name):
    spec = MODELS[name]
    params = init_params(spec, jax.random.PRNGKey(0))
    h, w, c = spec.input_shape
    x = jax.random.normal(jax.random.PRNGKey(1), (4, h, w, c))
    logits = forward(spec, params, x)
    assert logits.shape == (4, spec.num_classes)
    assert bool(jnp.isfinite(logits).all())


def test_loss_matches_ref_xent(cnn):
    params = init_params(cnn, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 28, 28, 1))
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 10)
    loss, correct = loss_and_correct(cnn, params, x, y)
    logits = forward(cnn, params, x)
    want = ref.softmax_xent_ref(logits, y)
    np.testing.assert_allclose(loss, want, rtol=1e-5)
    assert 0 <= float(correct) <= 8


def test_train_step_zero_lr_is_identity(cnn):
    ts = make_train_step(cnn)
    n = len(cnn.param_shapes)
    params = init_params(cnn, jax.random.PRNGKey(0))
    mom = [jnp.zeros_like(p) for p in params]
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 28, 28, 1))
    y = jax.random.randint(jax.random.PRNGKey(2), (4,), 0, 10)
    out = ts(*params, *mom, x, y, jnp.float32(0.0), jnp.float32(0.0))
    for p_new, p_old in zip(out[:n], params):
        np.testing.assert_array_equal(p_new, p_old)


def test_train_step_momentum_zero_buffers_carry_raw_gradient(cnn):
    """With mu=0 the returned momentum buffers are the raw gradients:
    new_p = p − lr·g must hold exactly."""
    ts = make_train_step(cnn)
    n = len(cnn.param_shapes)
    params = init_params(cnn, jax.random.PRNGKey(0))
    mom = [jnp.ones_like(p) for p in params]  # stale junk; must be ignored
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 28, 28, 1))
    y = jax.random.randint(jax.random.PRNGKey(2), (4,), 0, 10)
    lr = jnp.float32(0.1)
    out = ts(*params, *mom, x, y, lr, jnp.float32(0.0))
    for p_new, p_old, g in zip(out[:n], params, out[n : 2 * n]):
        np.testing.assert_allclose(p_new, p_old - lr * g, rtol=1e-6)


def test_train_step_decreases_loss_on_fixed_batch(cnn):
    ts = jax.jit(make_train_step(cnn))
    n = len(cnn.param_shapes)
    params = init_params(cnn, jax.random.PRNGKey(0))
    mom = [jnp.zeros_like(p) for p in params]
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 28, 28, 1))
    y = jax.random.randint(jax.random.PRNGKey(2), (16,), 0, 10)
    losses = []
    for _ in range(10):
        out = ts(*params, *mom, x, y, jnp.float32(0.05), jnp.float32(0.0))
        params = list(out[:n])
        mom = list(out[n : 2 * n])
        losses.append(float(out[2 * n]))
    assert losses[-1] < losses[0] * 0.7, losses


def test_eval_step_matches_loss_and_correct(alexnet):
    es = make_eval_step(alexnet)
    params = init_params(alexnet, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 32, 32, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 10)
    loss, correct = es(*params, x, y)
    want_loss, want_correct = loss_and_correct(alexnet, params, x, y)
    np.testing.assert_allclose(loss, want_loss, rtol=1e-6)
    np.testing.assert_allclose(correct, want_correct)


@pytest.mark.parametrize("name,batch", [("cnn", 16), ("alexnet", 16)])
def test_example_args_match_step_signature(name, batch):
    spec = MODELS[name]
    n = len(spec.param_shapes)
    train_args = example_args_train(spec, batch)
    assert len(train_args) == 2 * n + 4
    eval_args = example_args_eval(spec, batch)
    assert len(eval_args) == n + 2
    # Abstract-eval the jitted step against the declared signature.
    out = jax.eval_shape(make_train_step(spec), *train_args)
    assert len(out) == 2 * n + 2
    for got, shape in zip(out[:n], spec.param_shapes):
        assert got.shape == tuple(shape)
